"""Serving benchmark: continuous batching vs the fixed-decode-batch driver.

Both engines serve the same mixed-length trace (generations alternating
short/long around ``--gen``) from the same weights.  The fixed driver decodes
every group in lockstep for the *longest* generation in the group, so short
requests ride along as dead lanes; the continuous engine frees their lanes
and pages immediately and admits the next waiting prefill.  It also gets the
harder arrival model: requests trickle in every ``--arrival-every`` steps,
while the fixed driver batches as if all had arrived up front (an oracle
assumption in the baseline's favor).

Per engine the record captures tokens/s plus TTFT/TPOT p50/p99 (ms), and for
the continuous engine the schema-validated run manifest.  Engines are warmed
up (jit compile + one full trace) before the timed best-of-2 runs.

  PYTHONPATH=src python -m benchmarks.bench_serve --record --label pr7
  PYTHONPATH=src python -m benchmarks.bench_serve --check       # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict

import jax

from repro.configs import get_config
from repro.launch.serve import build_workload, run_fixed
from repro.models.lm import LM
from repro.serving import EngineConfig, ServeEngine, Telemetry

_RECORD_FILE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

# tracked smoke traces (8 continuous lanes vs fixed batches of 8):
#   mixed         — generations alternating 6/48, one arrival every 2 steps
#   shared-prefix — per-step arrivals, every 80-token prompt opens with the
#                   same 64-token system prefix; engine runs with CoW page
#                   sharing + chunked prefill (the --check gate trace)
#   chunked       — long 256-token prompts split into 32-token prefill
#                   chunks interleaved with decode; the metric chunking
#                   targets is the p99 inter-token gap (decode jitter), not
#                   mean-based TPOT, which amortizes the monolithic stall
_TRACE = dict(requests=16, prompt_len=16, gen=27, gen_spread=21,
              arrival_every=2)
_TRACES = {
    "mixed": dict(trace=_TRACE, engine={}),
    "shared-prefix": dict(
        trace=dict(requests=16, prompt_len=80, gen=27, gen_spread=26,
                   arrival_every=1, prefix_len=64),
        engine=dict(prefix_share=True, prefill_chunk=16)),
    "chunked": dict(
        trace=dict(requests=16, prompt_len=256, gen=27, gen_spread=26,
                   arrival_every=4),
        engine=dict(prefill_chunk=32, prefill_budget=64)),
    # same trace, monolithic prefill — the jitter baseline chunking targets
    "chunked-off": dict(
        trace=dict(requests=16, prompt_len=256, gen=27, gen_spread=26,
                   arrival_every=4),
        engine={}),
}
_LANES = 8
_PAGE_SIZE = 16
_CHECK_MIN_X = 1.4


def _latency_ms(tel: Telemetry) -> Dict[str, Dict[str, float]]:
    lat = tel.latency_summary()
    return {k: {"p50": round(v["p50"] * 1e3, 2), "p99": round(v["p99"] * 1e3, 2)}
            for k, v in lat.items() if k in ("ttft", "tpot", "gap")}


def bench_serve(arch: str, *, trace: Dict = None, lanes: int = _LANES,
                page_size: int = _PAGE_SIZE, runs: int = 2,
                engine_opts: Dict = None) -> Dict:
    trace = dict(trace or _TRACE)
    engine_opts = dict(engine_opts or {})
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = lambda: build_workload(cfg, **trace)
    max_gen = max(r.max_new_tokens for r in workload())
    max_len = trace["prompt_len"] + max_gen
    table_width = -(-max_len // page_size)
    ecfg = EngineConfig(lanes=lanes, page_size=page_size,
                        num_pages=lanes * table_width + 1, max_len=max_len,
                        **engine_opts)
    engine = ServeEngine(model, params, ecfg, arch=cfg.name)

    # warmup: one full trace through each engine (jit compile + caches);
    # the fixed driver reuses its jitted fns across calls via `fns`
    from repro.launch.serve import make_fixed_fns
    fns = make_fixed_fns(model)
    engine.run(workload())
    run_fixed(model, params, workload(), batch=lanes, fns=fns)

    best = {"continuous": None, "fixed": None}
    for _ in range(runs):
        engine.telemetry = Telemetry()          # fresh counters per timed run
        results, summary = engine.run(workload())
        cont = dict(tokens_per_s=round(summary["tokens_per_s"], 1),
                    wall_s=round(summary["wall_s"], 3),
                    steps=engine.telemetry.steps,
                    latency_ms=_latency_ms(engine.telemetry))
        if not best["continuous"] or cont["tokens_per_s"] > best["continuous"]["tokens_per_s"]:
            best["continuous"] = cont
            best["_n_tokens"] = sum(len(v) for v in results.values())

        tel = Telemetry()
        t0 = time.perf_counter()
        run_fixed(model, params, workload(), batch=lanes, fns=fns,
                  telemetry=tel)
        wall = time.perf_counter() - t0
        s = tel.run_summary(wall)
        fixed = dict(tokens_per_s=round(s["tokens_per_s"], 1),
                     wall_s=round(wall, 3), latency_ms=_latency_ms(tel))
        if not best["fixed"] or fixed["tokens_per_s"] > best["fixed"]["tokens_per_s"]:
            best["fixed"] = fixed

    manifest = engine.telemetry.build_manifest(
        arch=cfg.name, engine=engine.manifest_meta(),
        checkpoint={"restored": False, "dir": "", "algorithm": ""},
        wall_s=best["continuous"]["wall_s"])
    return dict(
        schema=1,
        arch=cfg.name,
        trace=trace,
        engine=dict(lanes=lanes, page_size=page_size,
                    num_pages=ecfg.num_pages, table_width=table_width,
                    **engine_opts),
        generated_tokens=best.pop("_n_tokens"),
        fixed=best["fixed"],
        continuous=best["continuous"],
        continuous_over_fixed=round(
            best["continuous"]["tokens_per_s"]
            / max(best["fixed"]["tokens_per_s"], 1e-9), 3),
        manifest=manifest,
    )


def append_record(record: Dict, path: str = _RECORD_FILE) -> None:
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--record", action="store_true",
                    help="append the run to BENCH_serve.json at the repo root")
    ap.add_argument("--label", default="dev",
                    help="record label (e.g. pr9) written with --record")
    ap.add_argument("--trace", choices=sorted(_TRACES), default="mixed",
                    help="named smoke trace to run (see module docstring)")
    ap.add_argument("--check", action="store_true",
                    help=f"exit 1 when continuous tokens/s is below "
                         f"{_CHECK_MIN_X}x the fixed-batch driver on the "
                         f"shared-prefix mixed-arrival smoke trace")
    args = ap.parse_args()

    name = "shared-prefix" if args.check else args.trace
    spec = _TRACES[name]
    r = bench_serve(args.arch, trace=spec["trace"],
                    engine_opts=spec["engine"],
                    runs=3 if args.check else 2)
    r["label"] = args.label
    r["trace_name"] = name
    r["date"] = time.strftime("%Y-%m-%d")
    print(json.dumps(r, indent=2))
    if args.record:
        append_record(r)
        print(f"appended record '{args.label}' to {_RECORD_FILE}")
    if args.check and r["continuous_over_fixed"] < _CHECK_MIN_X:
        print(f"FAIL: continuous engine is {r['continuous_over_fixed']:.2f}x "
              f"the fixed-batch driver (< {_CHECK_MIN_X}x)", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
