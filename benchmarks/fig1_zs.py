"""Fig. 1 — ZS pulse-budget vs SP-estimation accuracy trade-off.

(a) offsets of the estimated SP mean/std vs pulse budget N on a device
    array (paper: 512x512; reduced here), dw_min = 0.001.
(b) smallest N reaching <=1% relative mean error as dw_min shrinks —
    Thm 2.2's N = O(1/(delta * dw_min)) scaling.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zs
from repro.core.device import DeviceConfig, sample_device, symmetric_point


def run(quick: bool = True) -> List[str]:
    rows = []
    side = 64 if quick else 256
    key = jax.random.PRNGKey(0)

    # (a) offset vs pulse budget
    cfg = DeviceConfig(dw_min=0.001, sigma_pm=0.3, sigma_d2d=0.1, sigma_c2c=0.05)
    dp = sample_device(key, (side, side), cfg)
    sp = symmetric_point(dp, cfg)
    true_mean, true_std = float(jnp.mean(sp)), float(jnp.std(sp))
    budgets = [250, 500, 1000, 2000, 4000] if quick else [500, 1000, 2000, 4000, 8000]
    # tail_average=False: each chunk resumes Algorithm 1 from the device's
    # actual last iterate (an averaged state is not physically realizable as
    # a resume point, and re-averaging would compound across chunks)
    est = jnp.zeros((side, side))
    done = 0
    t0 = time.time()
    for n in budgets:
        est = zs.zs_estimate(jax.random.fold_in(key, n), est, dp, cfg,
                             n - done, tail_average=False)
        done = n
        mean_off = true_mean - float(jnp.mean(est))
        std_off = true_std - float(jnp.std(est))
        rel_err = abs(mean_off) / max(abs(true_mean), 1e-9)
        rows.append(f"fig1a_zs_offset_N{n},{(time.time()-t0)*1e6:.0f},"
                    f"mean_off={mean_off:.5f};std_off={std_off:.5f};rel={rel_err:.3f}")

    # (b) pulses to 1% mean error vs dw_min
    dwmins = [0.02, 0.01, 0.005, 0.0025] if quick else [0.02, 0.01, 0.005, 0.0025, 0.00125]
    for dw in dwmins:
        cfg2 = DeviceConfig(dw_min=dw, sigma_pm=0.3, sigma_d2d=0.1, sigma_c2c=0.05)
        dp2 = sample_device(jax.random.fold_in(key, 99), (side, side), cfg2)
        sp2 = symmetric_point(dp2, cfg2)
        tm = float(jnp.mean(sp2))
        t0 = time.time()
        w = jnp.zeros((side, side))
        n_total = 0
        found = -1
        chunk_n = max(200, int(0.2 / dw))
        while n_total < 80 / dw:
            w = zs.zs_estimate(jax.random.fold_in(key, n_total), w, dp2, cfg2,
                               chunk_n, tail_average=False)
            n_total += chunk_n
            if abs(tm - float(jnp.mean(w))) / max(abs(tm), 1e-9) <= 0.01:
                found = n_total
                break
        rows.append(f"fig1b_pulses_to_1pct_dwmin{dw},{(time.time()-t0)*1e6:.0f},"
                    f"N={found};pred_scaling=1/dwmin")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
