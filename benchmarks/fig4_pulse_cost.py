"""Fig. 4 (left) — total pulse cost to reach a target loss vs #states.

Two-stage (ZS calibration + TT-v2) pays N calibration pulses per element
*plus* training pulses; E-RIDER pays training pulses only. As the number of
conductance states grows (dw_min shrinks), the calibration bill explodes
(Thm 2.2) while E-RIDER's stays flat — the paper's headline efficiency
claim.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from .common import device_pair, train_image_model


def run(quick: bool = True) -> List[str]:
    rows = []
    # number of states = (tau_max + tau_min) / dw_min = 2 / dw_min
    dwmins = [0.1, 0.02] if quick else [0.1, 0.05, 0.02, 0.01, 0.004]
    epochs = 2 if quick else 4
    target = 1.2 if quick else 0.8
    for dw in dwmins:
        states = int(round(2.0 / dw))
        dev_p, dev_w = device_pair(dw_min=dw, ref_mean=0.2, ref_std=0.2)
        n_params = 784 * 256 + 256 * 128 + 128 * 10  # FCN analog elements

        # E-RIDER: training pulses only
        t0 = time.time()
        res_e = train_image_model(algorithm="erider", dev_p=dev_p, dev_w=dev_w,
                                  epochs=epochs, target_loss=target, seed=2)
        rows.append(f"fig4_erider_states{states},{(time.time()-t0)*1e6:.0f},"
                    f"train_pulses={res_e.pulses:.3e};steps_to_target={res_e.steps_to_target}")

        # two-stage: ZS pulses (Thm 2.2: N ~ 1/(delta*dw_min) per element)
        # + TT-v2 training pulses
        zs_budget_per_elem = min(8000, int(1.0 / dw * 40))
        zs_total = zs_budget_per_elem * n_params
        t0 = time.time()
        res_t = train_image_model(algorithm="ttv2", dev_p=dev_p, dev_w=dev_w,
                                  epochs=epochs, target_loss=target, seed=2)
        rows.append(f"fig4_zs_ttv2_states{states},{(time.time()-t0)*1e6:.0f},"
                    f"total_pulses={zs_total + res_t.pulses:.3e};"
                    f"zs_pulses={zs_total:.3e};train_pulses={res_t.pulses:.3e}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
