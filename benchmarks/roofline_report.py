"""Aggregate the dry-run JSONs into the §Dry-run / §Roofline tables."""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load_cells(out_dir: str = "results/dryrun", tag: str = ""):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if (r.get("tag") or "") != tag:
            continue
        cells.append(r)
    return cells


def fmt_table(cells, mesh: str = "pod16x16") -> str:
    hdr = ("| arch | shape | status | mem/dev GB | t_comp s | t_mem s | "
           "t_coll s | bottleneck | useful | roofline frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in cells:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped ({r['reason'][:40]}…) "
                         "| - | - | - | - | - | - | - |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | - | - | - | - |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['memory']['peak_per_device_gb']:.2f} "
            f"| {rf['t_compute']:.3f} | {rf['t_memory']:.3f} | {rf['t_collective']:.3f} "
            f"| {rf['bottleneck']} | {rf['useful_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def run(quick: bool = True) -> List[str]:
    cells = load_cells()
    rows = []
    n_ok = sum(1 for c in cells if c["status"] == "ok")
    n_skip = sum(1 for c in cells if c["status"] == "skipped")
    n_err = sum(1 for c in cells if c["status"] not in ("ok", "skipped"))
    rows.append(f"roofline_cells,0,ok={n_ok};skipped={n_skip};errors={n_err}")
    for c in cells:
        if c["status"] != "ok":
            continue
        rf = c["roofline"]
        rows.append(
            f"roofline_{c['arch']}_{c['shape']}_{c['mesh']},0,"
            f"bottleneck={rf['bottleneck']};frac={rf['roofline_fraction']:.4f};"
            f"useful={rf['useful_ratio']:.3f};mem_gb={c['memory']['peak_per_device_gb']}")
    return rows


if __name__ == "__main__":
    print(fmt_table(load_cells()))
