"""Fig. 2 — training with SPs estimated from different ZS pulse budgets.

Two-stage Residual Learning (paper Alg. 4) on the FCN stand-in task: the
static SP estimate comes from Algorithm 1 with N pulses. Small N leaves a
residual calibration error that degrades (or stalls) training — the
motivation for dynamic tracking.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zs
from repro.core.device import sample_device, symmetric_point
from repro.data import ImageDataset

from .common import device_pair, train_image_model


def run(quick: bool = True) -> List[str]:
    rows = []
    dev_p, dev_w = device_pair(dw_min=0.01, ref_mean=0.3, ref_std=0.3)
    data = ImageDataset(n_train=2048 if quick else 8192, n_test=1024, seed=11)
    epochs = 2 if quick else 5

    # ground-truth-SP run needs the actual tile device draws; we instead
    # sweep the *quality* of the estimate by running ZS for N pulses on a
    # mirror of each tile's device (same seed path as trainer.init).
    budgets = [0, 100, 1000] if quick else [0, 100, 500, 2000, 8000]
    for n in budgets:
        # sp_estimates=None -> Q=0 (uncalibrated); n>0 builds per-tile
        # estimates by simulating ZS on identically-sampled devices.
        sp_estimates = None
        label = "uncalibrated" if n == 0 else f"zs_N{n}"
        if n > 0:
            from repro.core.trainer import AnalogTrainer, TrainerConfig, partition_params
            from repro.core.tile import TileConfig
            from repro.models import convnets
            ccfg = convnets.ConvNetConfig(kind="fcn")
            params = convnets.init_convnet(jax.random.PRNGKey(0), ccfg)
            _, analog = partition_params(params, convnets.analog_filter)
            sp_estimates = {}
            for i, (p, w0) in enumerate(sorted(analog.items())):
                kk = jax.random.fold_in(jax.random.PRNGKey(1), i)
                kp, _, _ = jax.random.split(kk, 3)
                dp = sample_device(kp, w0.shape, dev_p)
                est = zs.zs_estimate(jax.random.fold_in(kk, 7),
                                     jnp.zeros(w0.shape), dp, dev_p, n)
                sp_estimates[p] = est
        t0 = time.time()
        res = train_image_model(
            algorithm="residual", dev_p=dev_p, dev_w=dev_w, epochs=epochs,
            data=data, sp_estimates=sp_estimates, seed=0)
        final = float(np.mean(res.losses[-20:]))
        rows.append(f"fig2_residual_{label},{(time.time()-t0)*1e6:.0f},"
                    f"final_loss={final:.4f};test_acc={res.test_acc:.4f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
