"""Tables 1-2 — robustness to nonzero SP reference (mean/std sweep).

TT-v2 vs AGAD vs E-RIDER on the FCN (Table 2) and LeNet-5 (Table 1)
stand-in tasks across reference mean/std offsets of the gradient-array
device. Paper claim to reproduce: TT-v2 degrades sharply with offset;
AGAD is robust; E-RIDER is best everywhere.
"""
from __future__ import annotations

import time
from typing import List

from .common import device_pair, train_image_model


def run(quick: bool = True) -> List[str]:
    rows = []
    if quick:
        grid = [(0.0, 0.05), (0.3, 0.4)]
        models = ["fcn"]
        epochs = 2
    else:
        grid = [(0.0, 0.05), (0.0, 0.4), (0.2, 0.4), (0.3, 0.4), (0.4, 1.0)]
        models = ["fcn", "lenet5"]
        epochs = 4
    algos = ["ttv2", "agad", "erider"]
    for model_kind in models:
        for mean, std in grid:
            dev_p, dev_w = device_pair(dw_min=0.4622, sigma_pm=0.7125,
                                       sigma_c2c=0.2174, ref_mean=mean, ref_std=std)
            for algo in algos:
                t0 = time.time()
                res = train_image_model(
                    algorithm=algo, model_kind=model_kind, dev_p=dev_p,
                    dev_w=dev_w, epochs=epochs, seed=1)
                sp = f";sp_err={res.sp_err:.4f}" if res.sp_err is not None else ""
                rows.append(
                    f"table12_{model_kind}_m{mean}_s{std}_{algo},"
                    f"{(time.time()-t0)*1e6:.0f},"
                    f"test_acc={res.test_acc:.4f}{sp}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
