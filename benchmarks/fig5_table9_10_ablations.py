"""Fig. 5 + Tables 9-10 — E-RIDER hyper-parameter ablations.

Fig. 5:   chopper probability p (p=0 degrades E-RIDER to RIDER).
Table 9:  moving-average stepsize eta.
Table 10: residual perturbation gamma (large gamma destabilizes).
"""
from __future__ import annotations

import time
from typing import List

from .common import device_pair, train_image_model


def _sweep(name: str, param: str, values, quick: bool) -> List[str]:
    rows = []
    dev_p, dev_w = device_pair(dw_min=0.25, sigma_pm=0.5, sigma_c2c=0.2,
                               ref_mean=0.3, ref_std=0.3)
    epochs = 2 if quick else 4
    for v in values:
        t0 = time.time()
        res = train_image_model(
            algorithm="erider", dev_p=dev_p, dev_w=dev_w, epochs=epochs,
            hp_overrides={param: v}, seed=3)
        sp = f";sp_err={res.sp_err:.4f}" if res.sp_err is not None else ""
        rows.append(f"{name}_{param}{v},{(time.time()-t0)*1e6:.0f},"
                    f"test_acc={res.test_acc:.4f}{sp}")
    return rows


def run(quick: bool = True) -> List[str]:
    rows = []
    ps = [0.0, 0.1] if quick else [0.0, 0.02, 0.05, 0.1, 0.2, 0.5]
    rows += _sweep("fig5_chopper", "chopper_p", ps, quick)
    etas = [0.05, 0.4] if quick else [0.01, 0.05, 0.2, 0.4, 0.6, 1.0]
    rows += _sweep("table9_eta", "eta", etas, quick)
    gammas = [0.1, 0.5] if quick else [0.05, 0.1, 0.2, 0.4, 0.5, 0.7]
    rows += _sweep("table10_gamma", "gamma", gammas, quick)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
