"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--full`` runs the
paper-scale sweeps (hours on this 1-core container); the default quick mode
exercises every benchmark end-to-end at reduced scale.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig1,table12]
"""
from __future__ import annotations

import argparse
import time

BENCHES = ("fig1", "fig2", "table12", "fig4", "ablations", "roofline",
           "tile_engine")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    print("name,us_per_call,derived")
    t_start = time.time()

    def emit(rows):
        for r in rows:
            print(r, flush=True)

    if "fig1" in only:
        from . import fig1_zs
        emit(fig1_zs.run(quick))
    if "fig2" in only:
        from . import fig2_sp_error
        emit(fig2_sp_error.run(quick))
    if "table12" in only:
        from . import table12_robustness
        emit(table12_robustness.run(quick))
    if "fig4" in only:
        from . import fig4_pulse_cost
        emit(fig4_pulse_cost.run(quick))
    if "ablations" in only:
        from . import fig5_table9_10_ablations
        emit(fig5_table9_10_ablations.run(quick))
    if "roofline" in only:
        from . import roofline_report
        emit(roofline_report.run(quick))
    if "tile_engine" in only:
        from . import bench_tile_engine
        emit(bench_tile_engine.run(quick))

    print(f"total,{(time.time() - t_start) * 1e6:.0f},benchmarks_done", flush=True)


if __name__ == "__main__":
    main()
