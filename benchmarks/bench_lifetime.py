"""Checkpoint-lifetime robustness benchmark: drift vs Global Drift
Compensation over a year of simulated retention.

Trains a smoke E-RIDER checkpoint in-process, then serves its effective
analog weights aged to t = 1 s ... 1 yr past programming — uncompensated
and GDC-corrected — and records each point's fidelity to the *validated
t0 model* plus the per-class drift-scale estimates (``repro.lifetime``).
The trajectory appends to ``BENCH_lifetime.json`` at the repo root.

Quality metric: serving a checkpoint is a fidelity contract against the
model that was validated at programming time, so the primary measure is
the mean KL divergence of the aged model's next-token predictions from
the t0 predictions over heldout contexts — the excess cross-entropy
(nats/token) a consumer of the deployment pays versus the reference.
Heldout-loss deltas and greedy-token agreement with t0 serving ride along
in the record (the smoke LM sits near its entropy plateau, so raw loss
deltas are too small to gate on; KL to the reference is not).

``--check`` gates the deployment story in CI:
  * uncompensated fidelity degrades monotonically with age and is clearly
    off-reference by 1 yr (KL above ``_CHECK_MIN_DEGRADE``);
  * GDC holds the 1 yr KL inside ``_CHECK_GDC_TOL`` of uncompensated;
  * at t = t0 the full GDC path (restore -> signature -> alpha ->
    correction) reproduces the ungated weights bit-exactly and serves
    token-identical generations.

  PYTHONPATH=src python -m benchmarks.bench_lifetime --record --label pr10
  PYTHONPATH=src python -m benchmarks.bench_lifetime --check       # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import BigramLM
from repro.models.lm import LM
from repro.serving import load_effective_params

_RECORD_FILE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_lifetime.json")

# the sweep: seconds past programming (t0). 1 s / 1 min / 1 h / 1 day /
# 1 month (Julian/12) / 1 year (Julian).
AGES = (("1s", 1.0), ("1min", 60.0), ("1h", 3600.0), ("1d", 86400.0),
        ("1mo", 2629800.0), ("1yr", 31557600.0))

_ARCH = "qwen2-0.5b"
_ALGORITHM = "erider"
_TRAIN_STEPS = 240
_TRAIN_LR = "0.3"
_EVAL_BATCHES = 4
_EVAL_BATCH = 8
_EVAL_SEQ = 64

# CI gates over KL-to-t0 (nats/token; the run is seed-deterministic, the
# slacks only cover compiler-level reassociation): measured on the smoke
# checkpoint, kl_raw ~ 0.0067 at 1yr and kl_gdc/kl_raw ~ 0.34.
_CHECK_MIN_DEGRADE = 0.004   # uncompensated 1yr KL must exceed this
_CHECK_GDC_TOL = 0.5         # GDC 1yr KL < this share of uncompensated
_CHECK_MONO_SLACK = 1e-4     # per-step monotonicity slack


def train_checkpoint(ckpt_dir: str) -> None:
    """Smoke E-RIDER training run writing a lifetime-aware checkpoint
    (the driver stores the GDC t0 signatures in the manifest)."""
    from repro.launch import train

    train.main(["--arch", _ARCH, "--smoke", "--algorithm", _ALGORITHM,
                "--steps", str(_TRAIN_STEPS), "--batch", str(_EVAL_BATCH),
                "--seq", str(_EVAL_SEQ), "--lr", _TRAIN_LR,
                "--ckpt-dir", ckpt_dir,
                "--ckpt-every", str(_TRAIN_STEPS), "--log-every",
                str(_TRAIN_STEPS)])


def make_eval(model):
    """Fidelity evaluator over fixed deterministic heldout batches.

    ``evaluate(params, ref_logits)`` returns ``(loss, kl)``: mean heldout
    LM loss, and mean KL of ``params``' next-token predictions from the
    reference logits (0.0 for the reference itself). Jitted once; only the
    params tree changes between sweep points."""
    data = BigramLM(vocab=model.cfg.vocab, seed=1234)
    batches = [
        {k: jnp.asarray(v)
         for k, v in data.batch(10_000 + i, _EVAL_BATCH, _EVAL_SEQ).items()}
        for i in range(_EVAL_BATCHES)
    ]
    logits_fn = jax.jit(
        lambda p, b: model.forward(p, b["tokens"], b.get("frames"))[0])
    loss_fn = jax.jit(lambda p, b: model.loss(p, b, None)[0])

    @jax.jit
    def kl_fn(ref, cur):
        lp_ref = jax.nn.log_softmax(ref)
        lp_cur = jax.nn.log_softmax(cur)
        return jnp.mean(jnp.sum(jnp.exp(lp_ref) * (lp_ref - lp_cur), axis=-1))

    def ref_logits(params):
        return [logits_fn(params, b) for b in batches]

    def evaluate(params, ref):
        loss = float(np.mean([np.asarray(loss_fn(params, b))
                              for b in batches]))
        kl = float(np.mean([np.asarray(kl_fn(r, logits_fn(params, b)))
                            for r, b in zip(ref, batches)]))
        return loss, kl

    return evaluate, ref_logits


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(la, lb))


def _serve_tokens(model, params, n: int = 4) -> Dict[str, list]:
    """Small greedy fixed-batch serve — the token-identity probe."""
    from repro.launch.serve import build_workload, make_fixed_fns, run_fixed

    workload = build_workload(model.cfg, requests=n, prompt_len=16, gen=8)
    results = run_fixed(model, params, workload, batch=n,
                        fns=_serve_tokens._fns)
    return {k: np.asarray(v).tolist() for k, v in results.items()}


_serve_tokens._fns = None


def bench_lifetime(ckpt_dir: str = "") -> Dict:
    cfg = get_config(_ARCH, smoke=True)
    model = LM(cfg)
    tmp = None
    if not ckpt_dir:
        tmp = tempfile.TemporaryDirectory(prefix="bench_lifetime_")
        ckpt_dir = os.path.join(tmp.name, "ckpt")
        train_checkpoint(ckpt_dir)
    evaluate, ref_logits = make_eval(model)

    load = lambda **kw: load_effective_params(
        model, ckpt_dir, _ALGORITHM, True, with_report=True, **kw)

    params_t0, _ = load()
    ref = ref_logits(params_t0)
    loss_t0, _ = evaluate(params_t0, ref)

    # --- t0 identity: the full GDC path must be a bit-exact no-op ---
    params_gdc_t0, rep0 = load(age_s=0.0, gdc=True)
    t0_bit_exact = _tree_equal(params_t0, params_gdc_t0)
    from repro.launch.serve import make_fixed_fns
    _serve_tokens._fns = make_fixed_fns(model)
    tok_plain = _serve_tokens(model, params_t0)
    tok_gdc = _serve_tokens(model, params_gdc_t0)
    t0_token_identical = tok_plain == tok_gdc

    def agreement(tok) -> float:
        """Per-token greedy agreement with the t0 serving run."""
        match = total = 0
        for rid, ref_toks in tok_plain.items():
            a = np.asarray(ref_toks)
            b = np.asarray(tok[rid])
            n = min(a.size, b.size)
            match += int(np.sum(a[:n] == b[:n]))
            total += max(a.size, b.size)
        return match / max(total, 1)

    sweep = []
    for name, age_s in AGES:
        p_raw, _ = load(age_s=age_s, gdc=False)
        p_gdc, rep = load(age_s=age_s, gdc=True)
        loss_raw, kl_raw = evaluate(p_raw, ref)
        loss_gdc, kl_gdc = evaluate(p_gdc, ref)
        # drift_scale: one summary over all classes, weighted equally
        cls = rep["drift_scale"]
        alphas = [v["mean"] for v in cls.values()]
        sweep.append({
            "age": name, "age_s": age_s,
            "kl_raw": round(kl_raw, 6),
            "kl_gdc": round(kl_gdc, 6),
            "loss_raw": round(loss_raw, 5),
            "loss_gdc": round(loss_gdc, 5),
            "delta_raw": round(loss_raw - loss_t0, 5),
            "delta_gdc": round(loss_gdc - loss_t0, 5),
            "agree_raw": round(agreement(_serve_tokens(model, p_raw)), 4),
            "agree_gdc": round(agreement(_serve_tokens(model, p_gdc)), 4),
            "drift_scale_mean": round(float(np.mean(alphas)), 5)
            if alphas else 1.0,
        })
        print(f"[lifetime] t0+{name:>4}: KL raw {kl_raw:.5f} | "
              f"gdc {kl_gdc:.5f} | agree raw "
              f"{sweep[-1]['agree_raw']:.2f} gdc "
              f"{sweep[-1]['agree_gdc']:.2f} | alpha~"
              f"{sweep[-1]['drift_scale_mean']:.3f}", flush=True)

    record = {
        "schema": 1,
        "arch": cfg.name,
        "algorithm": _ALGORITHM,
        "train_steps": _TRAIN_STEPS,
        "loss_t0": round(loss_t0, 5),
        "t0_signature": rep0["t0_signature"],
        "t0_bit_exact": t0_bit_exact,
        "t0_token_identical": t0_token_identical,
        "sweep": sweep,
    }
    if tmp is not None:
        tmp.cleanup()
    return record


def check(record: Dict) -> list:
    """CI gate: returns a list of failure strings (empty = pass)."""
    fails = []
    if not record["t0_bit_exact"]:
        fails.append("GDC path at t=t0 is not a bit-exact no-op")
    if not record["t0_token_identical"]:
        fails.append("GDC serving at t=t0 is not token-identical")
    if record["t0_signature"] != "checkpoint":
        fails.append("t0 signatures were not read from the checkpoint "
                     f"manifest (got {record['t0_signature']!r})")
    kls = [p["kl_raw"] for p in record["sweep"]]
    for a, b, p in zip(kls, kls[1:], record["sweep"][1:]):
        if b < a - _CHECK_MONO_SLACK:
            fails.append(f"uncompensated KL-to-t0 not monotone at "
                         f"{p['age']}: {b:.5f} < {a:.5f}")
    last = record["sweep"][-1]
    if last["kl_raw"] < _CHECK_MIN_DEGRADE:
        fails.append(f"uncompensated 1yr KL {last['kl_raw']:.5f} < "
                     f"{_CHECK_MIN_DEGRADE} — drift model not biting")
    if not (last["kl_gdc"] < _CHECK_GDC_TOL * last["kl_raw"]):
        fails.append(f"GDC 1yr KL {last['kl_gdc']:.5f} not within "
                     f"{_CHECK_GDC_TOL:.0%} of uncompensated "
                     f"{last['kl_raw']:.5f}")
    return fails


def append_record(record: Dict, path: str = _RECORD_FILE) -> None:
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="",
                    help="reuse an existing checkpoint instead of training")
    ap.add_argument("--record", action="store_true",
                    help="append the run to BENCH_lifetime.json at the repo root")
    ap.add_argument("--label", default="dev")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless drift degrades monotonically, GDC "
                         "holds the 1yr tolerance band, and the t0 GDC path "
                         "is bit-exact/token-identical")
    args = ap.parse_args()

    r = bench_lifetime(args.ckpt_dir)
    r["label"] = args.label
    r["date"] = time.strftime("%Y-%m-%d")
    print(json.dumps(r, indent=2))
    if args.record:
        append_record(r)
        print(f"appended record '{args.label}' to {_RECORD_FILE}")
    if args.check:
        fails = check(r)
        for f in fails:
            print(f"FAIL: {f}", file=sys.stderr)
        if fails:
            raise SystemExit(1)
        print("lifetime gate: OK")


if __name__ == "__main__":
    main()
