"""Shared harness for the paper-reproduction benchmarks.

Runs the paper's own workloads (FCN / LeNet-5 on the procedural MNIST
stand-in — the container is offline, see DESIGN.md §7) under any of the
seven analog training algorithms, with AIHWKit-style device presets, and
reports loss curves / test accuracy / cumulative pulse counts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.plan import AnalogPlan, TilePolicy
from repro.core.tile import TileConfig
from repro.core.trainer import AnalogTrainer, TrainerConfig
from repro.data import ImageDataset
from repro.models import convnets


def device_pair(
    *, dw_min: float = 0.01, ref_mean: float = 0.0, ref_std: float = 0.0,
    sigma_pm: float = 0.3, sigma_d2d: float = 0.1, sigma_c2c: float = 0.1,
):
    """(device_p, device_w): nonzero-SP reference on the gradient array P
    (the paper's Tables 1-2 setting), clean-ish main array."""
    dev_p = DeviceConfig(dw_min=dw_min, sigma_pm=sigma_pm, sigma_d2d=sigma_d2d,
                         sigma_c2c=sigma_c2c, ref_mean=ref_mean, ref_std=ref_std)
    dev_w = DeviceConfig(dw_min=dw_min, sigma_pm=sigma_pm, sigma_d2d=sigma_d2d,
                         sigma_c2c=sigma_c2c)
    return dev_p, dev_w


# per-algorithm tuned hyper-parameters (paper App. F.3 analogues).
# grad_norm='absmean' => lr_p counts average pulses/element/step on the fast
# array (AIHWKit auto-granularity semantics); lr_w acts in analog units.
_BASE = dict(grad_norm="absmean", buffered_transfer=True)
ALGO_HP: Dict[str, Dict] = {
    "sgd":      dict(_BASE, lr_w=5.0),
    "ttv1":     dict(_BASE, lr_p=5.0, lr_w=0.2, gamma=0.1),
    "ttv2":     dict(_BASE, lr_p=5.0, lr_w=0.2, gamma=0.1, threshold=1.0),
    "agad":     dict(_BASE, lr_p=5.0, lr_w=0.2, gamma=0.1, eta=0.05, chopper_p=0.1),
    "residual": dict(_BASE, lr_p=5.0, lr_w=0.2, gamma=0.1),
    "rider":    dict(_BASE, lr_p=5.0, lr_w=0.2, gamma=0.1, eta=0.05),
    "erider":   dict(_BASE, lr_p=5.0, lr_w=0.2, gamma=0.1, eta=0.05, chopper_p=0.1),
}


@dataclasses.dataclass
class RunResult:
    algorithm: str
    losses: List[float]
    test_acc: float
    pulses: float
    sp_err: Optional[float]
    steps_to_target: int
    wall_s: float


def train_image_model(
    *,
    algorithm: str = "erider",
    model_kind: str = "fcn",
    dev_p: DeviceConfig,
    dev_w: DeviceConfig,
    epochs: int = 3,
    batch: int = 64,
    lr: float = 0.2,
    seed: int = 0,
    data: Optional[ImageDataset] = None,
    target_loss: float = 0.0,
    hp_overrides: Optional[Dict] = None,
    sp_estimates=None,
    plan: Optional[AnalogPlan] = None,
) -> RunResult:
    """``plan``: optional AnalogPlan for mixed-policy runs; when omitted a
    one-policy plan is built from (algorithm, dev_p, dev_w) gated by the
    convnet's analog filter — the paper's single-device setting."""
    data = data or ImageDataset(n_train=4096, n_test=1024, seed=11)
    ccfg = convnets.ConvNetConfig(kind=model_kind)
    loss_fn = convnets.make_loss_fn(ccfg)

    hp = dict(ALGO_HP.get(algorithm, {}))
    hp.update(hp_overrides or {})
    tile = TileConfig(algorithm=algorithm, device_p=dev_p, device_w=dev_w, **hp)
    tcfg = TrainerConfig(
        tile=tile,
        digital=DigitalOptConfig(kind="sgdm", momentum=0.5),
        schedule=ScheduleConfig(kind="constant", base_lr=lr),
    )
    if plan is None:
        plan = AnalogPlan.of((convnets.analog_filter,
                              TilePolicy(tile, name=algorithm)),
                             analog_min_ndim=0)
    trainer = AnalogTrainer(loss_fn, tcfg, plan=plan)
    params = convnets.init_convnet(jax.random.PRNGKey(seed), ccfg)
    state = trainer.init(jax.random.PRNGKey(seed + 1), params, sp_estimates)
    step_fn = trainer.jit_step()

    losses: List[float] = []
    pulses = 0.0
    sp_err = None
    steps_to_target = -1
    step = 0
    t0 = time.time()
    for ep in range(epochs):
        for b in data.epoch(ep, batch):
            batch_j = {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
            state, m = step_fn(state, batch_j)
            step += 1
            loss = float(m["loss"])
            losses.append(loss)
            pulses += float(m.get("tile/pulses", 0.0))
            if "tile/sp_err" in m:
                sp_err = float(m["tile/sp_err"])
            if steps_to_target < 0 and target_loss > 0:
                recent = np.mean(losses[-20:])
                if len(losses) >= 20 and recent <= target_loss:
                    steps_to_target = step

    # test accuracy with the trained effective weights
    from repro.core import algorithms as alg
    from repro.core.trainer import merge_effective

    eff = merge_effective(state["params"], state["tiles"], tile)  # bank policies win
    accs = []
    for b in data.test_batches(256):
        logits = convnets.convnet_logits(eff, jnp.asarray(b["x"]), ccfg)
        accs.append(np.mean(np.argmax(np.asarray(logits), -1) == b["y"]))
    return RunResult(
        algorithm=algorithm,
        losses=losses,
        test_acc=float(np.mean(accs)),
        pulses=pulses,
        sp_err=sp_err,
        steps_to_target=steps_to_target,
        wall_s=time.time() - t0,
    )


def csv_row(name: str, wall_s: float, derived: str) -> str:
    """`name,us_per_call,derived` convention of benchmarks/run.py."""
    return f"{name},{wall_s * 1e6:.0f},{derived}"
