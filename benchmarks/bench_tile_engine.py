"""Tile-engine benchmark: looped (per-tile Python loop) vs grouped (batched,
shape-grouped TileBank) analog update path, plus a sharded mode.

The looped engine traces one full copy of the pulse-update graph per weight
matrix; the grouped engine traces one vmapped copy per distinct weight
*shape* (scanned per same-structure class). On a many-layer config this
collapses trace time and jitted program size from O(layers) to O(distinct
shapes), and the fused stacked updates are at least as fast to execute.

Measures, per engine:
  * trace+lower wall time of ``train_step``
  * lowered program size (StableHLO text bytes) and while-op count
  * compile wall time
  * steady-state steps/sec over a short timed run

``--sharded`` forces a small host device mesh (default 2x2 = (data, model))
and compares the ZeRO-sharded TileBank (stack dim on the data axis, member
dims on the model axis per the owning weight's rule) against the fully
replicated layout: per-device tile-state bytes and steps/s, emitted as a
JSON report (see benchmarks/README.md for the schema).

``--mixed`` measures the AnalogPlan mixed-policy path: the same shapes
trained once under a single policy and once under a two-policy plan (two
algorithms x two device presets -> two policy-split groups). ``--check``
exits nonzero when the mixed plan's steps/s falls more than 20% below the
single-policy grouped engine — the CI guard that per-group policy
specialization stays free.

``--record`` runs the standard 8-tile rule-diverse (256, 256) config (the
"8-layer benchmark config") through three engine variants — scanned vmap,
unrolled vmap, and the fused batched backend — and appends one record to
the repo-root ``BENCH_tile_engine.json`` trajectory file (steps/s, trace
time, program bytes, per-device tile-state bytes, and the restack count:
rank>=4 ``stablehlo.concatenate`` ops in the lowered step, which count the
per-step tile-stack rebuilds the class-keyed storage eliminates).
``--check-fused`` exits nonzero when the fused backend falls below 1.5x
the scanned vmap reference — the CI regression gate.

Run directly (``--smoke`` for the CI-sized config) or via benchmarks.run:

  PYTHONPATH=src python -m benchmarks.bench_tile_engine --smoke
  PYTHONPATH=src python -m benchmarks.bench_tile_engine --sharded
  PYTHONPATH=src python -m benchmarks.bench_tile_engine --mixed --check
  PYTHONPATH=src python -m benchmarks.bench_tile_engine --record --label pr6
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.plan import AnalogPlan, TilePolicy
from repro.core.tile import TileConfig
from repro.core.trainer import AnalogTrainer, TrainerConfig

from .common import csv_row


def _loss_fn(params, batch, rng):
    loss = sum(jnp.sum(v ** 2) for _, v in sorted(params.items()))
    return loss, {}


def _single_policy_plan(dev: DeviceConfig) -> AnalogPlan:
    tile = TileConfig(algorithm="erider", device_p=dev, device_w=dev)
    return AnalogPlan.of(("**", TilePolicy(tile, name="erider")))


def _build(n_layers: int, shape, engine: str):
    dev = DeviceConfig(dw_min=0.001, sigma_pm=0.3, sigma_d2d=0.1,
                       sigma_c2c=0.05)
    cfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
        engine=engine,
    )
    trainer = AnalogTrainer(_loss_fn, cfg, plan=_single_policy_plan(dev))
    params = {f"layer{i:02d}/w": 0.1 * jnp.ones(shape, jnp.float32)
              for i in range(n_layers)}
    state = trainer.init(jax.random.PRNGKey(0), params)
    return trainer, state


def bench_engine(engine: str, n_layers: int, shape, steps: int) -> Dict:
    trainer, state = _build(n_layers, shape, engine)
    batch = jnp.zeros(())

    t0 = time.perf_counter()
    lowered = jax.jit(trainer.train_step, donate_argnums=(0,)).lower(state, batch)
    t_trace = time.perf_counter() - t0
    text = lowered.as_text()

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    # warmup then timed steady-state steps
    state, m = compiled(state, batch)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        state, m = compiled(state, batch)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    return dict(
        engine=engine,
        trace_s=t_trace,
        compile_s=t_compile,
        program_bytes=len(text),
        program_whiles=text.count("stablehlo.while"),
        steps_per_s=steps / dt,
    )


def _sharded_step_rate(trainer, state, shardings, steps: int) -> float:
    step = jax.jit(trainer.train_step, in_shardings=(shardings, None),
                   donate_argnums=(0,))
    batch = jnp.zeros(())
    state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    return steps / (time.perf_counter() - t0)


def bench_sharded(n_layers: int, shape, steps: int,
                  data: int = 2, model: int = 2) -> Dict:
    """ZeRO-sharded vs replicated TileBank on a (data, model) host mesh."""
    from repro.distributed.sharding import replicated, state_shardings
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(data, model)
    dev = DeviceConfig(dw_min=0.001, sigma_pm=0.3, sigma_d2d=0.1,
                       sigma_c2c=0.05)
    cfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
    )
    plan = _single_policy_plan(dev)
    # rule-diverse layers: wq-family and wo-family stacks carry the model
    # axis on opposite member dims (spec-aware grouping keeps them apart)
    params = {}
    for i in range(n_layers // 2):
        params[f"layer{i:02d}/attn/wq"] = 0.1 * jnp.ones(shape, jnp.float32)
        params[f"layer{i:02d}/attn/wo"] = 0.1 * jnp.ones(shape, jnp.float32)

    def tile_bytes(state):
        leaves = jax.tree.leaves(state["tiles"])
        total = sum(leaf.nbytes for leaf in leaves)
        per_dev = sum(leaf.addressable_shards[0].data.nbytes
                      for leaf in leaves)
        return total, per_dev

    trainer = AnalogTrainer(_loss_fn, cfg, plan=plan, mesh=mesh)
    state = trainer.init(jax.random.PRNGKey(0), params)
    sh = state_shardings(state, mesh)
    state = jax.device_put(state, sh)
    total, per_dev_sharded = tile_bytes(state)
    sharded_rate = _sharded_step_rate(trainer, state, sh, steps)

    base = AnalogTrainer(_loss_fn, cfg, plan=plan)
    rstate = base.init(jax.random.PRNGKey(0), params)
    rsh = replicated(rstate, mesh)
    rstate = jax.device_put(rstate, rsh)
    _, per_dev_repl = tile_bytes(rstate)
    repl_rate = _sharded_step_rate(base, rstate, rsh, steps)

    return dict(
        mode="sharded",
        mesh=dict(data=data, model=model, devices=mesh.size),
        n_tiles=n_layers, member_shape=list(shape),
        groups=[g for g, _ in state["tiles"].index],
        tile_state_bytes_total=total,
        tile_state_bytes_per_device_replicated=per_dev_repl,
        tile_state_bytes_per_device_sharded=per_dev_sharded,
        reduction_x=round(per_dev_repl / max(per_dev_sharded, 1), 2),
        steps_per_s_sharded=round(sharded_rate, 2),
        steps_per_s_replicated=round(repl_rate, 2),
    )


# --- --record: the standard tracked config and its trajectory file --------

_RECORD_FILE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_tile_engine.json")
_RECORD_TILES = 8           # 4 layers x (attn/wq, attn/wo): rule-diverse
_RECORD_SHAPE = (256, 256)
_RECORD_STEPS = 30

_CONCAT_RE = re.compile(r"stablehlo\.concatenate.*->\s*tensor<([0-9x]+)x")


def count_restacks(hlo_text: str) -> int:
    """Rank>=4 concatenates in the lowered step = per-step tile restacks.

    A scanned class stack is (C, n, m, k); rebuilding it from per-group or
    per-tile pieces lowers to a rank-4+ concatenate. Legitimate rank-3
    concatenates (the flat per-class gradient stack, reshaped for free) and
    rank-2 key stacks don't count.
    """
    return sum(1 for m in _CONCAT_RE.finditer(hlo_text)
               if len(m.group(1).split("x")) >= 4)


def _record_params(n_tiles: int, shape):
    params = {}
    for i in range(n_tiles // 2):
        params[f"layer{i:02d}/attn/wq"] = 0.1 * jnp.ones(shape, jnp.float32)
        params[f"layer{i:02d}/attn/wo"] = 0.1 * jnp.ones(shape, jnp.float32)
    return params


def bench_record_variant(name: str, *, scan_groups: bool = True,
                         update_backend: str = "vmap",
                         metrics: str = "full",
                         n_tiles: int = _RECORD_TILES,
                         shape=_RECORD_SHAPE,
                         steps: int = _RECORD_STEPS) -> Dict:
    dev = DeviceConfig(dw_min=0.001, sigma_pm=0.3, sigma_d2d=0.1,
                       sigma_c2c=0.05)
    tile = TileConfig(algorithm="erider", device_p=dev, device_w=dev,
                      update_backend=update_backend, metrics=metrics)
    plan = AnalogPlan.of(("**", TilePolicy(tile, name="erider")))
    cfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
        scan_groups=scan_groups,
    )
    trainer = AnalogTrainer(_loss_fn, cfg, plan=plan)
    state = trainer.init(jax.random.PRNGKey(0), _record_params(n_tiles, shape))
    batch = jnp.zeros(())

    t0 = time.perf_counter()
    lowered = jax.jit(trainer.train_step, donate_argnums=(0,)).lower(
        state, batch)
    t_trace = time.perf_counter() - t0
    text = lowered.as_text()
    compiled = lowered.compile()

    state, m = compiled(state, batch)
    jax.block_until_ready(m["loss"])
    # best-of-3 timed loops: throughput on shared CI hosts drifts run to
    # run; the max is the machine-noise-robust estimate the gate compares
    best_dt = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = compiled(state, batch)
        jax.block_until_ready(m["loss"])
        best_dt = min(best_dt, time.perf_counter() - t0)
    tile_bytes = sum(leaf.addressable_shards[0].data.nbytes
                     for leaf in jax.tree.leaves(state["tiles"]))
    return dict(
        variant=name,
        steps_per_s=round(steps / best_dt, 2),
        trace_s=round(t_trace, 3),
        program_bytes=len(text),
        program_whiles=text.count("stablehlo.while"),
        restacks=count_restacks(text),
        tile_bytes_per_device=tile_bytes,
    )


def bench_record(label: str) -> Dict:
    variants = {}
    for name, kw in (
        ("scan", dict(scan_groups=True)),
        ("unroll", dict(scan_groups=False)),
        ("fused", dict(scan_groups=True, update_backend="fused")),
        # gate pair: diagnostic tile metrics down to pulse counts, so the
        # ratio measures the engines (RNG + scan/flatten data movement),
        # not the ~10ms of per-step SP diagnostics both backends share
        ("scan_pulses", dict(scan_groups=True, metrics="pulses")),
        ("fused_pulses", dict(scan_groups=True, update_backend="fused",
                              metrics="pulses")),
    ):
        variants[name] = bench_record_variant(name, **kw)
        print(json.dumps(variants[name]), flush=True)
    return dict(
        schema=1,
        label=label,
        date=time.strftime("%Y-%m-%d"),
        config=dict(n_tiles=_RECORD_TILES, member_shape=list(_RECORD_SHAPE),
                    algorithm="erider", steps=_RECORD_STEPS),
        variants=variants,
        fused_over_vmap=round(
            variants["fused_pulses"]["steps_per_s"]
            / max(variants["scan_pulses"]["steps_per_s"], 1e-9), 3),
    )


def append_record(record: Dict, path: str = _RECORD_FILE) -> None:
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(record)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")


def bench_mixed(n_layers: int, shape, steps: int) -> Dict:
    """Mixed-policy (AnalogPlan) vs single-policy grouped engine on the
    same shapes: one trainer, two (algorithm, device) policies -> two
    policy-split groups, vs all tiles under one policy/one group."""
    dev_a = DeviceConfig(dw_min=0.001, sigma_pm=0.3, sigma_d2d=0.1,
                         sigma_c2c=0.05)
    dev_b = DeviceConfig(dw_min=0.002, sigma_pm=0.5, sigma_d2d=0.1,
                         sigma_c2c=0.1, ref_mean=0.1, ref_std=0.1)
    pol_a = TilePolicy(TileConfig(algorithm="erider", device_p=dev_a,
                                  device_w=dev_a), name="erider-a")
    pol_b = TilePolicy(TileConfig(algorithm="rider", device_p=dev_b,
                                  device_w=dev_a), name="rider-b")
    plans = {
        "single": AnalogPlan.of(("**", pol_a)),
        "mixed": AnalogPlan.of(("**/attn/*", pol_a), ("**/mlp/*", pol_b)),
    }
    params = {}
    for i in range(n_layers // 2):
        params[f"layer{i:02d}/attn/wq"] = 0.1 * jnp.ones(shape, jnp.float32)
        params[f"layer{i:02d}/mlp/wi"] = 0.1 * jnp.ones(shape, jnp.float32)
    cfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
    )

    result: Dict = dict(mode="mixed", n_tiles=len(params),
                        member_shape=list(shape), steps=steps)
    batch = jnp.zeros(())
    for name, plan in plans.items():
        trainer = AnalogTrainer(_loss_fn, cfg, plan=plan)
        state = trainer.init(jax.random.PRNGKey(0), params)
        t0 = time.perf_counter()
        compiled = jax.jit(trainer.train_step, donate_argnums=(0,)) \
            .lower(state, batch).compile()
        t_compile = time.perf_counter() - t0
        state, m = compiled(state, batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = compiled(state, batch)
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        result[f"groups_{name}"] = [g for g, _ in state["tiles"].index]
        result[f"compile_s_{name}"] = round(t_compile, 3)
        result[f"steps_per_s_{name}"] = round(steps / dt, 2)
    result["mixed_over_single"] = round(
        result["steps_per_s_mixed"] / max(result["steps_per_s_single"], 1e-9), 3)
    return result


def run(quick: bool = True) -> List[str]:
    n_layers = 8 if quick else 48
    shape = (32, 32) if quick else (256, 256)
    steps = 10 if quick else 50
    rows = []
    results = {}
    for engine in ("looped", "grouped"):
        r = bench_engine(engine, n_layers, shape, steps)
        results[engine] = r
        rows.append(csv_row(
            f"tile_engine_{engine}_trace", r["trace_s"],
            f"program_bytes={r['program_bytes']};whiles={r['program_whiles']}"))
        rows.append(csv_row(
            f"tile_engine_{engine}_step", 1.0 / r["steps_per_s"],
            f"steps_per_s={r['steps_per_s']:.2f}"))
    g, l = results["grouped"], results["looped"]
    rows.append(csv_row(
        "tile_engine_speedup", 0.0,
        f"trace_x={l['trace_s'] / max(g['trace_s'], 1e-9):.2f};"
        f"program_x={l['program_bytes'] / max(g['program_bytes'], 1):.2f};"
        f"steps_x={g['steps_per_s'] / max(l['steps_per_s'], 1e-9):.2f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized config (default; kept for explicitness)")
    ap.add_argument("--full", action="store_true",
                    help="48 layers of 256x256 (minutes on CPU)")
    ap.add_argument("--sharded", action="store_true",
                    help="ZeRO-sharded vs replicated TileBank on a small "
                         "host mesh; prints a JSON report")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-policy AnalogPlan vs single-policy grouped "
                         "engine on the same shapes; prints a JSON report")
    ap.add_argument("--check", action="store_true",
                    help="with --mixed: exit 1 if the mixed plan regresses "
                         "steps/s by more than 20%% vs single-policy")
    ap.add_argument("--mesh", default="2x2",
                    help="sharded-mode mesh as DATAxMODEL (default 2x2)")
    ap.add_argument("--out", default="",
                    help="also write the sharded/mixed JSON report to this "
                         "path")
    ap.add_argument("--record", action="store_true",
                    help="run the tracked 8-tile 256x256 config (scan / "
                         "unroll / fused) and append one record to "
                         "BENCH_tile_engine.json at the repo root")
    ap.add_argument("--label", default="dev",
                    help="record label (e.g. pr6) written with --record")
    ap.add_argument("--check-fused", action="store_true",
                    help="exit 1 when the fused backend is below 1.5x the "
                         "scanned vmap reference (runs the tracked config; "
                         "composes with --record)")
    args = ap.parse_args()
    if args.record or args.check_fused:
        r = bench_record(args.label)
        print(json.dumps(r, indent=2))
        if args.record:
            append_record(r)
            print(f"appended record '{r['label']}' to {_RECORD_FILE}")
        if args.check_fused and r["fused_over_vmap"] < 1.5:
            print(f"FAIL: fused backend is {r['fused_over_vmap']:.2f}x the "
                  f"scanned vmap reference (< 1.5x)", file=sys.stderr)
            raise SystemExit(1)
        return
    if args.mixed:
        # (128, 128) members: big enough that per-group dispatch overhead
        # amortizes and the ratio measures the policy split, not kernel
        # launch latency (at (32, 32) even the single-policy engine is
        # dominated by fixed per-step costs)
        r = bench_mixed(8 if not args.full else 48,
                        (128, 128) if not args.full else (256, 256),
                        20 if not args.full else 50)
        text = json.dumps(r, indent=2)
        print(text)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text + "\n")
        if args.check and r["mixed_over_single"] < 0.8:
            print(f"FAIL: mixed-policy steps/s is "
                  f"{r['mixed_over_single']:.2f}x single-policy (< 0.8x)",
                  file=sys.stderr)
            raise SystemExit(1)
        return
    if args.sharded:
        data, model = (int(x) for x in args.mesh.split("x"))
        need = data * model
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            # the backend reads XLA_FLAGS at first init, which happens at
            # the jax.devices() call below — not at import
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count={need}")
        if len(jax.devices()) < need:
            raise SystemExit(
                f"--sharded needs {need} devices; run with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}")
        r = bench_sharded(8 if not args.full else 48,
                          (32, 32) if not args.full else (256, 256),
                          10 if not args.full else 50,
                          data=data, model=model)
        text = json.dumps(r, indent=2)
        print(text)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text + "\n")
        return
    print("name,us_per_call,derived")
    for row in run(quick=not args.full):
        print(row, flush=True)


if __name__ == "__main__":
    main()
