#!/usr/bin/env python
"""Check internal markdown links in README.md, docs/ and benchmarks/.

Validates every relative [text](target) link — external (http/mailto) and
pure-anchor links are skipped; targets resolve relative to the file that
contains them; a trailing #anchor is allowed (only the file part is
checked). Exits nonzero listing every broken link.

Run from anywhere:  python tools/check_doc_links.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_GLOBS = ["README.md", "docs", "benchmarks/README.md"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files():
    for entry in DOC_GLOBS:
        path = os.path.join(ROOT, entry)
        if os.path.isdir(path):
            for dirpath, _, names in os.walk(path):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(dirpath, n)
        elif os.path.isfile(path):
            yield path


def check_file(md_path):
    broken = []
    with open(md_path) as f:
        text = f.read()
    # drop fenced code blocks: JSON/code samples are not links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(md_path), rel))
        if not os.path.exists(resolved):
            broken.append((target, resolved))
    return broken


def main() -> int:
    n_files, n_links_bad = 0, 0
    for md in doc_files():
        n_files += 1
        for target, resolved in check_file(md):
            n_links_bad += 1
            print(f"BROKEN {os.path.relpath(md, ROOT)}: ({target}) "
                  f"-> {os.path.relpath(resolved, ROOT)} does not exist")
    if n_links_bad:
        print(f"{n_links_bad} broken link(s) across {n_files} file(s)")
        return 1
    print(f"OK: {n_files} markdown file(s), all internal links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
