#!/usr/bin/env python
"""Check internal markdown links in README.md, docs/ and benchmarks/.

Validates every relative [text](target) link — external (http/mailto)
links are skipped; targets resolve relative to the file that contains
them. ``#anchor`` fragments (including pure-anchor links within a file)
are resolved against the target's actual section headers using GitHub's
slug rules, so a link into a renamed ``docs/architecture.md`` section
fails instead of silently pointing at nothing. Exits nonzero listing
every broken link.

Run from anywhere:  python tools/check_doc_links.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_GLOBS = ["README.md", "docs", "benchmarks/README.md"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADER_RE = re.compile(r"^#{1,6}\s+(.+?)\s*$", re.M)
_FENCE_RE = re.compile(r"```.*?```", re.S)


def github_slug(header: str) -> str:
    """GitHub's anchor slug for one header line."""
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", header)  # [text](url)
    text = text.replace("`", "").strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


_anchor_cache: dict = {}


def anchors_of(md_path: str) -> set:
    """Every valid #anchor of a markdown file (duplicate headers get
    GitHub's -1/-2 suffixes)."""
    if md_path in _anchor_cache:
        return _anchor_cache[md_path]
    with open(md_path) as f:
        text = _FENCE_RE.sub("", f.read())
    out: set = set()
    seen: dict = {}
    for m in _HEADER_RE.finditer(text):
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    _anchor_cache[md_path] = out
    return out


def doc_files():
    for entry in DOC_GLOBS:
        path = os.path.join(ROOT, entry)
        if os.path.isdir(path):
            for dirpath, _, names in os.walk(path):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(dirpath, n)
        elif os.path.isfile(path):
            yield path


def check_file(md_path):
    broken = []
    with open(md_path) as f:
        text = f.read()
    # drop fenced code blocks: JSON/code samples are not links
    text = _FENCE_RE.sub("", text)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        rel, _, anchor = target.partition("#")
        resolved = (md_path if not rel else os.path.normpath(
            os.path.join(os.path.dirname(md_path), rel)))
        if not os.path.exists(resolved):
            broken.append((target, resolved, "does not exist"))
            continue
        if anchor and resolved.endswith(".md"):
            if anchor not in anchors_of(resolved):
                broken.append(
                    (target, resolved, f"has no section anchor #{anchor}"))
    return broken


def main() -> int:
    n_files, n_links_bad = 0, 0
    for md in doc_files():
        n_files += 1
        for target, resolved, why in check_file(md):
            n_links_bad += 1
            print(f"BROKEN {os.path.relpath(md, ROOT)}: ({target}) "
                  f"-> {os.path.relpath(resolved, ROOT)} {why}")
    if n_links_bad:
        print(f"{n_links_bad} broken link(s) across {n_files} file(s)")
        return 1
    print(f"OK: {n_files} markdown file(s), all internal links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
