#!/usr/bin/env python
"""Static-analysis gate: graph contracts + AST lint, diffed against a
checked-in baseline.

Modes:

  python tools/check_graphs.py                 # run both passes, print
  python tools/check_graphs.py --check         # + diff GRAPH_BASELINE.json
                                               #   (what CI runs)
  python tools/check_graphs.py --update-baseline
  python tools/check_graphs.py --mutate restack --only train_step_scanned
                                               # prove the gate bites
  python tools/check_graphs.py --lint-only     # skip the (slow) lowering

``--check`` fails when:

  * any contract has violations,
  * a registered contract is missing from the baseline (stale baseline —
    rerun ``--update-baseline`` and commit the diff),
  * a baselined contract is no longer registered (coverage silently
    shrank),
  * a contract's limits are *looser* than the baselined ones (raised
    ceilings, grown allowlists, disabled checks), or
  * the linter reports a finding not present in the baseline.

The JSON report (``--report``) is validated against ``REPORT_SCHEMA``
before writing, so downstream tooling can rely on its shape.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

SCHEMA_VERSION = 1

REPORT_SCHEMA = {
    "type": "object",
    "required": ["version", "ok", "contracts", "lint"],
    "additionalProperties": False,
    "properties": {
        "version": {"const": SCHEMA_VERSION},
        "ok": {"type": "boolean"},
        "mutant": {"type": ["string", "null"]},
        "contracts": {
            "type": "array",
            "items": {
                "type": "object",
                "required": ["name", "ok", "violations", "stats", "limits"],
                "additionalProperties": False,
                "properties": {
                    "name": {"type": "string"},
                    "ok": {"type": "boolean"},
                    "violations": {
                        "type": "array",
                        "items": {
                            "type": "object",
                            "required": ["rule", "detail"],
                            "additionalProperties": False,
                            "properties": {"rule": {"type": "string"},
                                           "detail": {"type": "string"}},
                        },
                    },
                    "stats": {"type": "object"},
                    "limits": {"type": "object"},
                },
            },
        },
        "lint": {
            "type": "array",
            "items": {
                "type": "object",
                "required": ["path", "line", "rule", "message"],
                "additionalProperties": False,
                "properties": {
                    "path": {"type": "string"},
                    "line": {"type": "integer", "minimum": 0},
                    "rule": {"type": "string"},
                    "message": {"type": "string"},
                },
            },
        },
        "baseline_failures": {"type": "array", "items": {"type": "string"}},
    },
}


def build_report(only=None, mutant=None, lint_only=False):
    from repro.analysis import run_lint

    contracts = []
    if not lint_only:
        from repro.analysis import graph_contracts as gc

        names = sorted(gc.CONTRACTS) if only is None else list(only)
        for name in names:
            if name not in gc.CONTRACTS:
                raise SystemExit(f"unknown contract {name!r}; have: "
                                 f"{', '.join(sorted(gc.CONTRACTS))}")
            res = gc.run_contract(name, mutant=mutant)
            entry = res.to_json()
            entry["limits"] = gc.CONTRACTS[name].limits_json()
            contracts.append(entry)

    lint = [f.to_json() for f in run_lint(os.path.join(REPO, "src", "repro"))]
    report = {
        "version": SCHEMA_VERSION,
        "ok": all(c["ok"] for c in contracts) and not lint,
        "mutant": mutant,
        "contracts": contracts,
        "lint": lint,
    }
    return report


def diff_baseline(report, baseline) -> list:
    """Failure strings for --check (empty = gate passes)."""
    from repro.analysis.contracts import loosened
    from repro.analysis import graph_contracts as gc

    failures = []
    base_contracts = baseline.get("contracts", {})
    seen = set()
    for entry in report["contracts"]:
        name = entry["name"]
        seen.add(name)
        for v in entry["violations"]:
            failures.append(f"{name}: [{v['rule']}] {v['detail']}")
        if name not in base_contracts:
            failures.append(
                f"{name}: not in baseline (new contract? run "
                "--update-baseline and commit GRAPH_BASELINE.json)")
            continue
        loose = loosened(gc.CONTRACTS[name],
                         base_contracts[name].get("limits", {}))
        for item in loose:
            failures.append(f"{name}: contract loosened: {item}")
    for name in base_contracts:
        if name not in seen:
            failures.append(
                f"{name}: in baseline but no longer registered "
                "(contract coverage shrank)")

    base_lint = {(f["path"], f["rule"], f["message"])
                 for f in baseline.get("lint", [])}
    for f in report["lint"]:
        if (f["path"], f["rule"], f["message"]) not in base_lint:
            failures.append(
                f"lint {f['path']}:{f['line']}: [{f['rule']}] {f['message']}")
    return failures


def baseline_from_report(report) -> dict:
    return {
        "version": SCHEMA_VERSION,
        "contracts": {
            c["name"]: {"limits": c["limits"], "stats": c["stats"]}
            for c in report["contracts"]
        },
        "lint": list(report["lint"]),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="diff against the baseline; nonzero on drift")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current run")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "GRAPH_BASELINE.json"))
    ap.add_argument("--report", default="", metavar="PATH",
                    help="write the schema-validated JSON report here")
    ap.add_argument("--only", action="append", metavar="NAME",
                    help="run only this contract (repeatable)")
    ap.add_argument("--lint-only", action="store_true",
                    help="AST lint pass only (no lowering/compiling)")
    ap.add_argument("--mutate", choices=("restack", "host_transfer", "f64",
                                         "no_donate"),
                    help="plant a defect in every built entrypoint; the "
                    "run must FAIL (mutation-testing the gate)")
    args = ap.parse_args(argv)

    report = build_report(only=args.only, mutant=args.mutate,
                          lint_only=args.lint_only)

    failures = []
    if args.check:
        if args.mutate:
            raise SystemExit("--check and --mutate are mutually exclusive")
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            baseline = {"version": SCHEMA_VERSION, "contracts": {},
                        "lint": []}
        failures = diff_baseline(report, baseline)
        report["baseline_failures"] = failures
        report["ok"] = report["ok"] and not failures

    from repro.serving.schema import validate
    validate(report, REPORT_SCHEMA)

    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)

    for entry in report["contracts"]:
        mark = "ok " if entry["ok"] else "FAIL"
        stats = entry["stats"]
        print(f"[{mark}] {entry['name']}: "
              f"restacks={stats['restacks']} "
              f"aliased={stats['aliased_outputs']} "
              f"hbm={stats['hbm_bytes']:.0f}B "
              f"dtypes={','.join(stats['dtypes'])}")
        for v in entry["violations"]:
            print(f"       [{v['rule']}] {v['detail']}")
    if report["lint"]:
        print(f"{len(report['lint'])} lint finding(s):")
        for f in report["lint"]:
            print(f"  {f['path']}:{f['line']}: [{f['rule']}] {f['message']}")
    else:
        print("lint: clean")
    for msg in failures:
        print(f"BASELINE: {msg}")

    if args.update_baseline:
        if args.mutate:
            raise SystemExit("refusing to baseline a mutated run")
        if args.only or args.lint_only:
            raise SystemExit("baseline updates must run every contract")
        if not report["ok"]:
            raise SystemExit("refusing to baseline a failing run")
        with open(args.baseline, "w") as f:
            json.dump(baseline_from_report(report), f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"baseline written: {args.baseline}")

    if args.mutate:
        bad = [c["name"] for c in report["contracts"] if c["ok"]]
        if bad:
            print(f"MUTATION ESCAPED ({args.mutate}): {', '.join(bad)}")
            return 1
        print(f"mutation '{args.mutate}' caught by every contract")
        return 0

    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
