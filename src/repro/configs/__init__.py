"""Architecture registry: the 10 assigned archs + the paper's own nets."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import SHAPES, ModelConfig, ShapeSpec, input_specs, shape_applicable, sub_quadratic  # noqa: F401

ARCHS = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-14b": "qwen3_14b",
    "gemma3-4b": "gemma3_4b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen2-0.5b": "qwen2_0_5b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {name: get_config(name, smoke) for name in ARCHS}
