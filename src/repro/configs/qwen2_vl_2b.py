"""qwen2-vl-2b [vlm] — M-RoPE text backbone; vision frontend stub.

28L d_model=1536 12H (kv=2) head_dim=128 d_ff=8960 vocab=151936
[arXiv:2409.12191; hf]. M-RoPE sections (t,h,w) = (16,24,24) over the
head_dim/2=64 rotary channels. input_specs() provides precomputed patch
embeddings fused additively with token embeddings (frontend STUB).
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    pattern=("attn",),
    n_periods=28,
    tail=(),
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    tied_embeddings=True,
    frontend="vision",
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=32,
    d_ff=128,
    vocab=512,
    pattern=("attn",),
    n_periods=2,
    tail=(),
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(4, 6, 6),
    tied_embeddings=True,
    frontend="vision",
    attn_chunk=32,
    dtype=jnp.float32,
)
