"""mixtral-8x7b [moe] — 8 experts top-2, GQA kv=8, sliding-window attention.

32L d_model=4096 32H (kv=8) head_dim=128 expert d_ff=14336 vocab=32000,
SWA window 4096 [arXiv:2401.04088; hf].
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    pattern=("attn_local",),
    n_periods=32,
    tail=(),
    window=4096,
    n_experts=8,
    top_k=2,
    d_ff_expert=14336,
    capacity_factor=1.25,
    moe_group=2048,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("attn_local",),
    n_periods=2,
    tail=(),
    window=16,
    n_experts=4,
    top_k=2,
    d_ff_expert=128,
    capacity_factor=1.5,
    moe_group=64,
    attn_chunk=32,
    dtype=jnp.float32,
)
