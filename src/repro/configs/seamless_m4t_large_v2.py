"""seamless-m4t-large-v2 [audio] — encoder-decoder transformer backbone.

24L enc + 24L dec, d_model=1024 16H (kv=16) head_dim=64 d_ff=8192
vocab=256206 [arXiv:2308.11596; hf]. The speech/audio frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, S, d) consumed
directly by the encoder. Decode shapes lower the decoder serve_step with
self- and cross-attention caches.
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    pattern=("attn",),
    n_periods=24,
    tail=(),
    n_enc_layers=24,
    frontend="audio",
    activation="gelu",
    glu=False,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("attn",),
    n_periods=2,
    tail=(),
    n_enc_layers=2,
    frontend="audio",
    activation="gelu",
    glu=False,
    attn_chunk=32,
    dtype=jnp.float32,
)
