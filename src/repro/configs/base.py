"""Model configuration schema shared by all 10 assigned architectures.

Every architecture file in this package exports:
  CONFIG        — the exact full-size config from the assignment
  SMOKE_CONFIG  — a reduced same-family config for CPU smoke tests
  (both are ``ModelConfig`` instances)

``input_specs(cfg, shape_name)`` builds ShapeDtypeStruct stand-ins for every
model input of a (arch x shape) dry-run cell — no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer kinds used in ``pattern``:
#   attn        — full causal self-attention + MLP
#   attn_local  — sliding-window causal self-attention + MLP
#   mla         — multi-head latent attention (DeepSeek-style) + MLP/MoE
#   rec         — RG-LRU recurrent block (Griffin) + MLP
#   ssm         — Mamba-2 SSD block (no separate MLP)
# ---------------------------------------------------------------------------

LAYER_KINDS = ("attn", "attn_local", "mla", "rec", "ssm")

# Parameter-path substrings that stay on the digital optimizer in every
# analog plan (the paper's setups keep embeddings / vocab heads / positional
# tables digital — DESIGN.md §5). Consumed by ``repro.api.lm_plan``, which
# turns each into a leading ``re:`` DIGITAL rule, replacing the old
# ``default_analog_filter`` predicate.
DIGITAL_PATH_PATTERNS: Tuple[str, ...] = ("embed", "vocab", "lm_head", "pos")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | hybrid | ssm | audio | vlm
    # core dims
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    # layer pattern: `pattern` repeats `n_periods` times, then `tail`.
    # n_periods * len(pattern) + len(tail) == n_layers.
    pattern: Tuple[str, ...] = ("attn",)
    n_periods: int = 4
    tail: Tuple[str, ...] = ()
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    window: int = 0                  # sliding window for attn_local
    rope_base: float = 10000.0
    rope_type: str = "rope"          # rope | mrope
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    attn_chunk: int = 1024           # KV chunk for memory-efficient attention
    attn_logit_softcap: float = 0.0
    # MLA (deepseek/minicpm)
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head_dim: int = 0
    mla_absorbed: bool = False   # latent-space attention (see §Perf)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0      # leading layers with dense FFN
    capacity_factor: float = 1.25
    moe_group: int = 2048            # GShard dispatch group size
    moe_impl: str = "einsum"         # einsum | ragged
    aux_loss_coef: float = 0.01
    # SSM (mamba2)
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # RG-LRU (griffin)
    d_rnn: int = 0                   # 0 -> d_model
    rglru_c: float = 8.0
    conv_k: int = 4
    # encoder-decoder (seamless)
    n_enc_layers: int = 0            # 0 -> decoder-only
    frontend: Optional[str] = None   # None | audio | vision (stubs)
    # misc
    activation: str = "silu"         # silu | gelu
    glu: bool = True
    tied_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    residual_scale: float = 1.0      # minicpm depth scaling
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # training
    remat: bool = True
    microbatch: int = 1              # gradient-accumulation microbatches

    def __post_init__(self):
        assert self.n_periods * len(self.pattern) + len(self.tail) == self.n_layers, (
            self.name, self.n_layers, self.pattern, self.n_periods, self.tail)
        for k in self.pattern + self.tail:
            assert k in LAYER_KINDS, k

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return self.pattern * self.n_periods + self.tail

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        return _count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Shapes (the four assigned input-shape cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def sub_quadratic(cfg: ModelConfig) -> bool:
    """True if the arch supports long_500k (not pure full attention)."""
    kinds = set(cfg.layer_kinds)
    if kinds & {"ssm", "rec"}:
        return True
    if "attn_local" in kinds and cfg.window > 0:
        # pure-SWA (mixtral) or mostly-local (gemma3) qualify
        return True
    return False


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(applicable, reason)."""
    if shape == "long_500k" and not sub_quadratic(cfg):
        return False, "pure full-attention arch; 500k decode cache excluded (DESIGN.md §5)"
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one shape cell.

    train:   {tokens (B,S) i32, labels (B,S) i32 [, frames (B,S,d)]}
    prefill: {tokens (B,S) i32 [, frames]}
    decode:  {tokens (B,1) i32, pos () i32}  — cache specs come from the
             model's ``cache_specs`` (state, not input).
    """
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    i32 = jnp.int32

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    out: Dict[str, Any] = {}
    if spec.kind == "train":
        out["tokens"] = tok((B, S))
        out["labels"] = tok((B, S))
    elif spec.kind == "prefill":
        out["tokens"] = tok((B, S))
    else:  # decode
        out["tokens"] = tok((B, 1))
        out["pos"] = jax.ShapeDtypeStruct((), i32)

    if cfg.frontend is not None and spec.kind != "decode":
        # modality stub: precomputed frame/patch embeddings
        out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
    if cfg.is_encdec and spec.kind == "decode":
        # decoder steps attend to a precomputed encoder output
        out["enc_out"] = jax.ShapeDtypeStruct((B, min(S, 32768), cfg.d_model), cfg.dtype)
    return out


# ---------------------------------------------------------------------------
# parameter counting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig, kind: str) -> int:
    d = cfg.d_model
    if kind == "mla":
        q = d * cfg.q_lora + cfg.q_lora * cfg.n_heads * (cfg.qk_nope + cfg.qk_rope)
        kv = d * (cfg.kv_lora + cfg.qk_rope)
        kv += cfg.kv_lora * cfg.n_heads * (cfg.qk_nope + cfg.v_head_dim)
        o = cfg.n_heads * cfg.v_head_dim * d
        return q + kv + o
    hd = cfg.head_dim
    return d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv * hd) * 2


def _mlp_params(cfg: ModelConfig, layer_idx: int) -> int:
    d = cfg.d_model
    if cfg.n_experts and layer_idx >= cfg.first_dense_layers:
        e_ff = cfg.d_ff_expert or cfg.d_ff
        n_mats = 3 if cfg.glu else 2
        routed = cfg.n_experts * n_mats * d * e_ff
        shared = cfg.n_shared * n_mats * d * e_ff
        router = d * cfg.n_experts
        return routed + shared + router
    n_mats = 3 if cfg.glu else 2
    return n_mats * d * cfg.d_ff


def _layer_params(cfg: ModelConfig, kind: str, layer_idx: int) -> int:
    d = cfg.d_model
    if kind == "ssm":
        din = cfg.d_inner
        zxbcdt = d * (2 * din + 2 * cfg.ssm_groups * cfg.d_state + cfg.ssm_heads)
        return zxbcdt + din * d + cfg.ssm_heads * 2 + din
    if kind == "rec":
        dr = cfg.rnn_width
        mix = d * dr * 2 + dr * d + 2 * dr * dr + cfg.conv_k * dr
        return mix + _mlp_params(cfg, layer_idx)
    return _attn_params(cfg, kind) + _mlp_params(cfg, layer_idx)


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab * cfg.d_model  # embeddings
    if not cfg.tied_embeddings:
        total += cfg.vocab * cfg.d_model
    kinds = cfg.layer_kinds
    for i, k in enumerate(kinds):
        p = _layer_params(cfg, k, i)
        if active_only and cfg.n_experts and k in ("attn", "attn_local", "mla") and i >= cfg.first_dense_layers:
            e_ff = cfg.d_ff_expert or cfg.d_ff
            n_mats = 3 if cfg.glu else 2
            inactive = (cfg.n_experts - cfg.top_k) * n_mats * cfg.d_model * e_ff
            p -= inactive
        total += p
    if cfg.is_encdec:
        # encoder layers (full attention, no causal) + cross-attn in decoder
        for i in range(cfg.n_enc_layers):
            total += _layer_params(cfg, "attn", i)
        total += cfg.n_layers * _attn_params(cfg, "attn")  # cross-attn
    return int(total)
