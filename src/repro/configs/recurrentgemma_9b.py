"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (GQA kv=1, MQA) head_dim=256 d_ff=12288 vocab=256000
[arXiv:2402.19427]. Pattern: [rec, rec, attn_local] x 12 + [rec, rec] tail;
local window 2048; GeGLU; gemma-style sqrt(d) embedding scaling.
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    pattern=("rec", "rec", "attn_local"),
    n_periods=12,
    tail=("rec", "rec"),
    window=2048,
    d_rnn=4096,
    conv_k=4,
    activation="gelu",
    glu=True,
    embed_scale=True,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=2,
    n_kv=1,
    head_dim=32,
    d_ff=128,
    vocab=512,
    pattern=("rec", "rec", "attn_local"),
    n_periods=1,
    tail=("rec", "rec"),
    window=16,
    d_rnn=64,
    conv_k=4,
    activation="gelu",
    glu=True,
    embed_scale=True,
    attn_chunk=32,
    dtype=jnp.float32,
)
