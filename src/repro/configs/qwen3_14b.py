"""qwen3-14b [dense] — GQA kv=8 with per-head qk-norm, no QKV bias.

40L d_model=5120 40H (kv=8) head_dim=128 d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-8B family].
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    pattern=("attn",),
    n_periods=40,
    tail=(),
    qk_norm=True,
    qkv_bias=False,
    rope_base=1000000.0,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("attn",),
    n_periods=2,
    tail=(),
    qk_norm=True,
    qkv_bias=False,
    attn_chunk=32,
    dtype=jnp.float32,
)
