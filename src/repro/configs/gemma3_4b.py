"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

34L d_model=2560 8H (kv=4) head_dim=256 d_ff=10240 vocab=262144
[hf:google/gemma-3 family]. Pattern: [local x5, global] x5 + [local x4]
tail; local window 1024; qk-norm; GeGLU; sqrt(d) embedding scaling.
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    pattern=("attn_local",) * 5 + ("attn",),
    n_periods=5,
    tail=("attn_local",) * 4,
    window=1024,
    qk_norm=True,
    rope_base=1000000.0,
    activation="gelu",
    glu=True,
    embed_scale=True,
    tied_embeddings=True,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=8,
    d_model=64,
    n_heads=2,
    n_kv=1,
    head_dim=32,
    d_ff=128,
    vocab=512,
    pattern=("attn_local",) * 5 + ("attn",),
    n_periods=1,
    tail=("attn_local",) * 2,
    window=16,
    qk_norm=True,
    activation="gelu",
    glu=True,
    embed_scale=True,
    tied_embeddings=True,
    attn_chunk=32,
    dtype=jnp.float32,
)
