"""deepseek-v2-236b [moe] — MLA + 2 shared + 160 routed experts top-6.

60L d_model=5120 128H, MLA (q_lora=1536, kv_lora=512, qk_nope=128,
qk_rope=64, v_head=128), expert d_ff=1536, dense first layer d_ff=12288,
vocab=102400 [arXiv:2405.04434; hf].
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv=128,
    head_dim=192,          # qk_nope + qk_rope (expanded form)
    d_ff=12288,            # dense FFN (first layer)
    vocab=102400,
    pattern=("mla",),
    n_periods=60,
    tail=(),
    q_lora=1536,
    kv_lora=512,
    qk_nope=128,
    qk_rope=64,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    n_shared=2,
    d_ff_expert=1536,
    first_dense_layers=1,
    capacity_factor=1.25,
    moe_group=2048,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=24,
    d_ff=128,
    vocab=512,
    pattern=("mla",),
    n_periods=3,
    tail=(),
    q_lora=32,
    kv_lora=16,
    qk_nope=16,
    qk_rope=8,
    v_head_dim=16,
    n_experts=8,
    top_k=2,
    n_shared=1,
    d_ff_expert=32,
    first_dense_layers=1,
    capacity_factor=1.5,
    moe_group=64,
    attn_chunk=32,
    dtype=jnp.float32,
)
