"""qwen2-0.5b [dense] — GQA kv=2 with QKV bias, tied embeddings.

24L d_model=896 14H (kv=2) head_dim=64 d_ff=4864 vocab=151936
[arXiv:2407.10671; hf].
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    head_dim=64,
    d_ff=4864,
    vocab=151936,
    pattern=("attn",),
    n_periods=24,
    tail=(),
    qkv_bias=True,
    tied_embeddings=True,
    rope_base=1000000.0,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    pattern=("attn",),
    n_periods=2,
    tail=(),
    qkv_bias=True,
    tied_embeddings=True,
    attn_chunk=32,
    dtype=jnp.float32,
)
