"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).

64L d_model=2560, d_inner=5120 (expand 2), 80 SSD heads x P=64,
ssm_state N=128, conv k=4, vocab=50280 [arXiv:2405.21060].
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,           # unused (attention-free)
    n_kv=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    pattern=("ssm",),
    n_periods=64,
    tail=(),
    d_state=128,
    d_conv=4,
    expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_groups=1,
    tied_embeddings=True,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=1,
    n_kv=1,
    head_dim=16,
    d_ff=0,
    vocab=512,
    pattern=("ssm",),
    n_periods=3,
    tail=(),
    d_state=16,
    d_conv=4,
    expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    ssm_groups=1,
    tied_embeddings=True,
    dtype=jnp.float32,
)
