"""minicpm3-4b [dense] — MLA attention with depth-scaled residuals.

62L d_model=2560 40H d_ff=6400 vocab=73448, MLA (q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64) [hf:openbmb/MiniCPM3-4B].
residual_scale = 1.4 / sqrt(62) (scale_depth).
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv=40,
    head_dim=96,           # qk_nope + qk_rope (expanded form)
    d_ff=6400,
    vocab=73448,
    pattern=("mla",),
    n_periods=62,
    tail=(),
    q_lora=768,
    kv_lora=256,
    qk_nope=64,
    qk_rope=32,
    v_head_dim=64,
    residual_scale=1.4 / 62 ** 0.5,
    attn_chunk=1024,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm3-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=4,
    head_dim=24,
    d_ff=128,
    vocab=512,
    pattern=("mla",),
    n_periods=3,
    tail=(),
    q_lora=32,
    kv_lora=16,
    qk_nope=16,
    qk_rope=8,
    v_head_dim=16,
    residual_scale=1.4 / 3 ** 0.5,
    attn_chunk=32,
    dtype=jnp.float32,
)
