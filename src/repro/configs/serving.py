"""Per-architecture serving defaults for the continuous-batching engine.

The training-side ``ModelConfig`` stays serving-agnostic; these defaults map
a model family onto engine knobs (decode lanes, KV page size).  Page size
trades allocator granularity against gather width: recurrent/SSM families
carry O(1) state per lane, so their "pages" only meter the few attention
layers they mix in (or none at all — the allocator still bounds admission).
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeDefaults:
    lanes: int = 8
    page_size: int = 16


_FAMILY_DEFAULTS = {
    "dense": ServeDefaults(lanes=8, page_size=16),
    "moe": ServeDefaults(lanes=4, page_size=16),
    "hybrid": ServeDefaults(lanes=8, page_size=16),
    "ssm": ServeDefaults(lanes=16, page_size=32),
    "audio": ServeDefaults(lanes=4, page_size=16),
    "vlm": ServeDefaults(lanes=8, page_size=16),
}


def serve_defaults(cfg: ModelConfig) -> ServeDefaults:
    return _FAMILY_DEFAULTS.get(cfg.family, ServeDefaults())
