"""Per-architecture serving defaults for the continuous-batching engine.

The training-side ``ModelConfig`` stays serving-agnostic; these defaults map
a model family onto engine knobs (decode lanes, KV page size, prefill
chunking).  Page size trades allocator granularity against gather width:
recurrent/SSM families carry O(1) state per lane, so their "pages" only
meter the few attention layers they mix in (or none at all — the allocator
still bounds admission).  ``prefill_chunk`` bounds the decode stall a single
long-prompt admission can inflict (0 = whole-prompt prefill); the engine
gates it off for families where chunk boundaries are not exactness-safe
(rec scans, misaligned SSM chunks).  ``prefix_share`` opts a family into
copy-on-write prompt-prefix page sharing (attention page-pool layers only).
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeDefaults:
    lanes: int = 8
    page_size: int = 16
    prefill_chunk: int = 0
    prefix_share: bool = False


_FAMILY_DEFAULTS = {
    "dense": ServeDefaults(lanes=8, page_size=16, prefill_chunk=64),
    "moe": ServeDefaults(lanes=4, page_size=16, prefill_chunk=64),
    # hybrid includes rec layers -> the engine disables chunking anyway
    "hybrid": ServeDefaults(lanes=8, page_size=16),
    "ssm": ServeDefaults(lanes=16, page_size=32),
    "audio": ServeDefaults(lanes=4, page_size=16),
    "vlm": ServeDefaults(lanes=8, page_size=16, prefill_chunk=64),
}


def serve_defaults(cfg: ModelConfig) -> ServeDefaults:
    return _FAMILY_DEFAULTS.get(cfg.family, ServeDefaults())
