"""repro.api — the user-facing training facade.

One import gives everything needed to train any model on heterogeneous
analog hardware:

    from repro.api import (AnalogPlan, AnalogTrainer, TilePolicy, DIGITAL,
                           RERAM_HFO2_RIDER, ECRAM_ERIDER, lm_plan)

    plan = AnalogPlan.of(
        ("**/wq", RERAM_HFO2_RIDER),     # attention queries: noisy ReRAM + RIDER
        ("**/mlp/*", ECRAM_ERIDER),      # MLPs: ECRAM + E-RIDER
        ("re:embed|lm_head", DIGITAL),   # embeddings stay digital
        default=DIGITAL,
    )
    trainer = AnalogTrainer(loss_fn, TrainerConfig(...), plan=plan)

Rules are matched against parameter tree paths in order — the FIRST match
wins — as globs (``**`` crosses ``/``), ``re:``-prefixed regexes, or
``(path, leaf) -> bool`` predicates. Each distinct policy keeps its own
tile stacks: the grouped engine keys groups on (shape, dtype, sharding-rule
template, policy), so one jitted train_step mixes device presets AND
algorithms while staying O(distinct structures) in program size.

``lm_plan`` prepends the standard digital exclusions
(``configs.base.DIGITAL_PATH_PATTERNS``: embeddings / vocab heads /
positional tables) to your rules — the plan-API successor of
``default_analog_filter``.

Named policy presets below pair a device preset (core/device.py PRESETS,
paper Table 3) with the algorithm the paper runs on it; use them directly
or as templates for ``TilePolicy.of``.
"""
from __future__ import annotations

from repro.configs.base import DIGITAL_PATH_PATTERNS
from repro.core.device import PRESETS, DeviceConfig  # noqa: F401
from repro.core.plan import (  # noqa: F401
    DIGITAL, AnalogPlan, TilePolicy, plan_partition, policy_from_json,
    policy_to_json)
from repro.core.tile import TileConfig  # noqa: F401
from repro.core.trainer import AnalogTrainer, TrainerConfig  # noqa: F401

# ---------------------------------------------------------------------------
# named policy presets: device preset x algorithm pairs from the paper's
# experiments (Tables 1-2 run RIDER/E-RIDER on the noisy ReRAM presets;
# the idealized device is the digital-like SGD reference)
# ---------------------------------------------------------------------------

#: Few-state HfO2 ReRAM (hardest preset) under RIDER (Alg. 2).
RERAM_HFO2_RIDER = TilePolicy.of("rider", "reram_hfo2", name="reram-hfo2-rider")
#: Few-state HfO2 ReRAM under E-RIDER (Alg. 3, the headline method).
RERAM_HFO2_ERIDER = TilePolicy.of("erider", "reram_hfo2", name="reram-hfo2-erider")
#: ReRAM-OM preset under RIDER.
RERAM_OM_RIDER = TilePolicy.of("rider", "reram_om", name="reram-om-rider")
#: ReRAM-OM preset under E-RIDER.
RERAM_OM_ERIDER = TilePolicy.of("erider", "reram_om", name="reram-om-erider")
#: ECRAM-style device (~1000 states) under E-RIDER.
ECRAM_ERIDER = TilePolicy.of("erider", "ecram", name="ecram-erider")
#: ECRAM-style device under residual learning + ZS (two-stage, Alg. 4).
ECRAM_RESIDUAL = TilePolicy.of("residual", "ecram", name="ecram-residual")
#: High-precision softbounds device under TT-v2.
SOFTBOUNDS_TTV2 = TilePolicy.of("ttv2", "softbounds_2000", name="softbounds-ttv2")
#: Idealized symmetric device under plain analog SGD (reference).
IDEAL_SGD = TilePolicy.of("sgd", "ideal", name="ideal-sgd")


def lm_plan(*rules, default=DIGITAL, analog_min_ndim: int = 2) -> AnalogPlan:
    """Standard LM plan: embeddings / vocab heads / positional tables stay
    digital (DIGITAL_PATH_PATTERNS), then ``rules`` apply in order.

    ``lm_plan(("**", policy))`` reproduces the old
    ``default_analog_filter`` + single-TileConfig behavior;
    ``lm_plan(("re:attn", pol_a), ("**", pol_b))`` trains attention and
    the rest on different stacks.
    """
    digital_rules = tuple(
        (f"re:(?i){pat}", DIGITAL) for pat in DIGITAL_PATH_PATTERNS)
    return AnalogPlan.of(*digital_rules, *rules, default=default,
                         analog_min_ndim=analog_min_ndim)


def plan_from_spec(spec: str, make_tile_cfg) -> AnalogPlan:
    """CLI ``--algorithm`` value -> lm_plan (the one parser behind
    ``repro.launch.{train,dryrun,serve}``).

    ``spec`` is a single algorithm name (one policy on every analog leaf)
    or a comma-separated list of ``pattern=algorithm`` rules matched in
    order — globs, ``re:`` regexes, or bare substrings (``"attn"`` means
    ``"re:attn"``); ``digital`` is a valid algorithm::

        erider
        attn=rider,**=erider
        re:mlp/(wi|wg)$=ttv2,wo=rider,**=erider

    ``make_tile_cfg(algorithm)`` builds each named policy's TileConfig.
    """

    def policy(algo: str) -> TilePolicy:
        if algo == "digital":
            return DIGITAL
        return TilePolicy(make_tile_cfg(algo), name=algo)

    if "=" not in spec:
        return lm_plan(("**", policy(spec.strip())))
    rules = []
    for part in spec.split(","):
        # tolerate natural spacing ("attn=rider, **=erider"): an unstripped
        # pattern would compile to a glob that can never match, silently
        # leaving those layers digital
        pat, _, algo = (s.strip() for s in part.partition("="))
        if not any(ch in pat for ch in "*?") and not pat.startswith("re:"):
            pat = "re:" + pat  # bare name -> substring match
        rules.append((pat, policy(algo)))
    return lm_plan(*rules)
