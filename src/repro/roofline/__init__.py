from . import analysis, hlo_cost  # noqa: F401
