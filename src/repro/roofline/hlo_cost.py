"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` has two blind spots for our dry-runs:
it reports the *per-device* module and it counts while-loop bodies ONCE —
a layer-stack scan of 59 periods is undercounted 59x. The optimized HLO
text, however, annotates every static loop with
``backend_config={"known_trip_count":{"n":...}}``.

This module parses the HLO into computations, prices each instruction, and
walks the call graph from ENTRY multiplying loop bodies by their trip
counts. Prices:

  flops            — dot ops: 2 * batch * M * N * K from the dot dimension
                     numbers + operand shapes (convolutions priced from the
                     result shape * kernel volume).
  memory bytes     — operand + result bytes of every instruction at fusion
                     boundaries (internals of a fusion are free = the fusion
                     is one HBM round trip, which is how the TPU behaves).
  collective bytes — result bytes of all-reduce/all-gather/reduce-scatter/
                     all-to-all/collective-permute, trip-weighted.

Numbers are per-device (the SPMD module); multiply by chip count for
whole-cluster totals.
"""
from __future__ import annotations

import math
import re
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .hlo_common import (COLLECTIVES, DTYPE_BYTES, TRIP_RE,
                         shape_bytes_elems)

# legacy aliases (pre-hlo_common callers import these from here)
_DTYPE_BYTES = DTYPE_BYTES
_TRIP_RE = TRIP_RE

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
# TYPE may be a tuple spanning `/*index=N*/` comments; lazy-match up to the
# first ` opcode(` boundary (opcode = word chars immediately before '(').
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([a-z][a-zA-Z\d\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=|body=)%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DIMS_RE = {
    k: re.compile(k + r"=\{([\d,]*)\}")
    for k in ("lhs_contracting_dims", "rhs_contracting_dims",
              "lhs_batch_dims", "rhs_batch_dims")
}

# ops with no real data movement
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "custom-call"}


_type_bytes_elems = shape_bytes_elems


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.strip() or line.strip().startswith("//"):
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands run until the first unparenthesized ')'
    depth = 0
    out = []
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
            token += ch
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
            token += ch
        else:
            token += ch
    # Operands may be bare names ("%name") or carry inline types
    # ("f32[64,128]{1,0} %name", older XLA text form); shapes contain commas,
    # so extract the %name token from each comma-split fragment.
    for part in token.split(","):
        m = re.search(r"%([\w.\-]+)", part)
        if m:
            out.append(m.group(1))
    return out


def _dot_flops(instr: Instr, types: Dict[str, str]) -> float:
    ops = _operand_names(instr.rest)
    if len(ops) < 2:
        return 0.0
    lhs_t = types.get(ops[0], "")
    rhs_t = types.get(ops[1], "")
    lhs = _dims_of(lhs_t)
    rhs = _dims_of(rhs_t)
    if not lhs or not rhs:
        return 0.0

    def dims(key):
        m = _DIMS_RE[key].search(instr.rest)
        if not m or not m.group(1):
            return []
        return [int(x) for x in m.group(1).split(",")]

    lc = dims("lhs_contracting_dims")
    rc = dims("rhs_contracting_dims")
    lb = dims("lhs_batch_dims")
    rb = dims("rhs_batch_dims")
    batch = math.prod([lhs[i] for i in lb]) if lb else 1
    k = math.prod([lhs[i] for i in lc]) if lc else 1
    m_dim = math.prod([d for i, d in enumerate(lhs) if i not in lc + lb])
    n_dim = math.prod([d for i, d in enumerate(rhs) if i not in rc + rb])
    return 2.0 * batch * m_dim * k * n_dim


def _conv_flops(instr: Instr, types: Dict[str, str]) -> float:
    ops = _operand_names(instr.rest)
    if len(ops) < 2:
        return 0.0
    out_elems = _type_bytes_elems(instr.type_str)[1]
    kern = _dims_of(types.get(ops[1], ""))
    if not kern:
        return 0.0
    # kernel volume x input features: all kernel dims except output feature
    vol = math.prod(kern)
    out_feat = kern[-1] if len(kern) >= 1 else 1
    return 2.0 * out_elems * max(vol // max(out_feat, 1), 1)


def _instr_cost(instr: Instr, types: Dict[str, str]) -> Cost:
    c = Cost()
    if instr.op in _FREE and instr.op != "custom-call":
        return c
    rb, _ = _type_bytes_elems(instr.type_str)
    if instr.op == "dynamic-slice":
        # hardware reads only the slice, not the sliced-from array
        c.bytes = 2.0 * rb
        return c
    if instr.op == "dynamic-update-slice":
        # in-place: writes only the update region (operand 1)
        ops = _operand_names(instr.rest)
        ub = _type_bytes_elems(types.get(ops[1], ""))[0] if len(ops) > 1 else rb
        c.bytes = 2.0 * ub
        return c
    ob = 0
    for name in _operand_names(instr.rest):
        ob += _type_bytes_elems(types.get(name, ""))[0]
    c.bytes = rb + ob
    if instr.op == "dot":
        c.flops = _dot_flops(instr, types)
    elif instr.op == "convolution":
        c.flops = _conv_flops(instr, types)
    for coll in COLLECTIVES:
        if instr.op == coll or instr.op == coll + "-start":
            c.coll[coll] = float(rb)
    return c


_INDEX_RE = re.compile(r"index=(\d+)")
_DIRECTION_RE = re.compile(r"direction=(\w+)")
_CONST_INT_RE = re.compile(r"^\s*(-?\d+)\s*\)")


def _const_int(name: str, comp: Computation) -> Optional[int]:
    """Integer value of a scalar constant instruction (following copies)."""
    by_name = {i.name: i for i in comp.instrs}
    seen = set()
    while name in by_name and name not in seen:
        seen.add(name)
        instr = by_name[name]
        if instr.op == "constant":
            m = _CONST_INT_RE.match(instr.rest)
            return int(m.group(1)) if m else None
        if instr.op in ("copy", "bitcast", "convert"):
            ops = _operand_names(instr.rest)
            if not ops:
                return None
            name = ops[0]
            continue
        return None
    return None


def derive_trip_count(instr: Instr, comp: Computation,
                      comps: Dict[str, Computation]) -> Optional[int]:
    """Trip count of a canonical counted ``while`` loop, derived from its
    condition/init/body when the ``known_trip_count`` backend_config is
    absent (other XLA versions/backends strip or omit it).

    The lowered form of ``lax.scan``/``fori_loop`` is:
      condition ROOT:  compare(get-tuple-element(param, index=K), bound),
                       direction=LT
      init:            tuple element K is a scalar constant
      body ROOT tuple: element K = add(get-tuple-element(.., index=K), step)
    Returns ``ceil((bound - init) / step)`` or None when the loop does not
    match (genuinely dynamic condition)."""
    cond_m = _COND_RE.search(instr.rest)
    body_m = _CALLS_RE.search(instr.rest)
    if not cond_m or not body_m:
        return None
    cond = comps.get(cond_m.group(1))
    body = comps.get(body_m.group(1))
    if cond is None or body is None or not cond.instrs or not body.instrs:
        return None
    # --- condition: ROOT compare(counter, bound) direction=LT ---
    root = cond.instrs[-1]
    if root.op != "compare":
        return None
    dm = _DIRECTION_RE.search(root.rest)
    if not dm or dm.group(1) != "LT":
        return None
    ops = _operand_names(root.rest)
    if len(ops) < 2:
        return None
    cond_by_name = {i.name: i for i in cond.instrs}
    gte = cond_by_name.get(ops[0])
    if gte is None or gte.op != "get-tuple-element":
        return None
    km = _INDEX_RE.search(gte.rest)
    if not km:
        return None
    k = int(km.group(1))
    bound = _const_int(ops[1], cond)
    if bound is None:
        return None
    # --- init: element K of the while's operand tuple ---
    while_ops = _operand_names(instr.rest)
    comp_by_name = {i.name: i for i in comp.instrs}
    init_tuple = comp_by_name.get(while_ops[0]) if while_ops else None
    seen = set()
    while init_tuple is not None and init_tuple.op in ("copy", "bitcast") \
            and init_tuple.name not in seen:
        seen.add(init_tuple.name)
        t_ops = _operand_names(init_tuple.rest)
        init_tuple = comp_by_name.get(t_ops[0]) if t_ops else None
    if init_tuple is None or init_tuple.op != "tuple":
        return None
    t_ops = _operand_names(init_tuple.rest)
    if k >= len(t_ops):
        return None
    init = _const_int(t_ops[k], comp)
    if init is None:
        return None
    # --- body: element K of the ROOT tuple is add(counter, step) ---
    broot = body.instrs[-1]
    if broot.op != "tuple":
        return None
    b_ops = _operand_names(broot.rest)
    if k >= len(b_ops):
        return None
    body_by_name = {i.name: i for i in body.instrs}
    upd = body_by_name.get(b_ops[k])
    seen = set()
    while upd is not None and upd.op in ("copy", "bitcast") \
            and upd.name not in seen:
        seen.add(upd.name)
        u_ops = _operand_names(upd.rest)
        upd = body_by_name.get(u_ops[0]) if u_ops else None
    if upd is None or upd.op != "add":
        return None
    step = None
    for o in _operand_names(upd.rest):
        v = _const_int(o, body)
        if v is not None:
            step = v
            break
    if not step or step <= 0 or bound <= init:
        return None
    return -(-(bound - init) // step)


def analyze_hlo(hlo: str) -> Cost:
    comps = parse_module(hlo)
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Cost()
        comp = comps[name]
        types = {i.name: i.type_str for i in comp.instrs}
        total = Cost()
        for instr in comp.instrs:
            if instr.op == "while":
                m = TRIP_RE.search(instr.rest)
                if m:
                    trips = int(m.group(1))
                else:
                    # no known_trip_count annotation (stripped or absent on
                    # this backend): derive it from the canonical counted-
                    # loop structure before giving up
                    trips = derive_trip_count(instr, comp, comps)
                if trips is None:
                    # genuinely dynamic-condition loop; price the body once
                    # rather than silently dropping it, and say so — a
                    # mispriced loop poisons the roofline
                    trips = 1
                    warnings.warn(
                        f"while loop '{instr.name}' (in computation "
                        f"'{comp.name}') has no known_trip_count annotation "
                        "and no derivable counted-loop structure; pricing "
                        "its body with trip count 1",
                        RuntimeWarning, stacklevel=2)
                body = _CALLS_RE.search(instr.rest)
                cond = _COND_RE.search(instr.rest)
                if body:
                    total += comp_cost(body.group(1), stack + (name,)).scaled(trips)
                if cond:
                    total += comp_cost(cond.group(1), stack + (name,)).scaled(trips)
                # while op itself moves its carried tuple once per iteration
                rb, _ = _type_bytes_elems(instr.type_str)
                total += Cost(bytes=float(rb))
                continue
            if instr.op == "conditional":
                mb = _BRANCH_RE.search(instr.rest)
                if mb:
                    branches = [b.strip().lstrip("%") for b in mb.group(1).split(",")]
                    sub = [comp_cost(b, stack + (name,)) for b in branches]
                    if sub:  # worst-case branch
                        total += max(sub, key=lambda c: (c.flops, c.bytes))
                continue
            if instr.op in ("fusion", "call", "reduce", "sort", "scatter",
                            "reduce-window", "select-and-scatter", "map",
                            "all-reduce", "reduce-scatter"):
                total += _instr_cost(instr, types)
                # fused computations' dots (rare) still need pricing
                mcalls = _CALLS_RE.search(instr.rest)
                if mcalls and instr.op in ("fusion", "call"):
                    inner = comp_cost(mcalls.group(1), stack + (name,))
                    total += Cost(flops=inner.flops, coll=dict(inner.coll))
                continue
            total += _instr_cost(instr, types)
        memo[name] = total
        return total

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.replace("ENTRY ", "").strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].instrs)) if comps else ""
    return comp_cost(entry)
