"""Shared HLO-text vocabulary: dtype widths, shape/collective regexes.

One home for the tables that ``hlo_cost.py`` (the trip-count-aware cost
model) and ``analysis.py`` (the roofline report) used to duplicate — the
two copies had drifted (the roofline copy was missing the f8 fnuz
variants). ``repro.analysis`` (the graph-contract checker) builds on the
same vocabulary, so a dtype XLA learns tomorrow is added in exactly one
place.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

# bytes per element of every dtype token XLA prints in shape strings
DTYPE_BYTES: Dict[str, int] = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# dtypes that never carry real payload (control/placeholder types)
ZERO_WIDTH_DTYPES = frozenset(("token", "opaque"))

# `dtype[dims]` anywhere in a type string; tuple types repeat the pattern
# (possibly interleaved with `/*index=N*/` comments, which this skips).
SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# one collective instruction per line of optimized HLO text: name, result
# type (tuple or flat), opcode, tolerating the async `-start` suffix
COLL_RE = re.compile(
    r"(\w+[\d.]*)\s*=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(" + "|".join(COLLECTIVES) + r")"
    r"(?:-start)?\(",
)

# static-loop annotation on `while` ops in optimized HLO
TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")

# ops classed as host transfers by the graph-contract checker: data leaves
# or enters the device outside the normal parameter/result path
HOST_TRANSFER_OPS = frozenset(
    ("infeed", "outfeed", "send", "send-done", "recv", "recv-done"))


def shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    """(total bytes, total elements) over every shape in ``type_str``.
    Unknown dtype tokens are skipped (matches the cost model's behavior)."""
    total_b = 0
    total_e = 0
    for m in SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


def shape_bytes(type_str: str) -> int:
    return shape_bytes_elems(type_str)[0]


def shape_dtypes(type_str: str):
    """Every known dtype token appearing in ``type_str`` (tuple-aware)."""
    return [m.group(1) for m in SHAPE_RE.finditer(type_str)
            if m.group(1) in DTYPE_BYTES]
