"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh) cell, on TPU v5e constants:

  compute    = HLO_FLOPs / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s per ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
all devices). collective_bytes is parsed from the optimized HLO text: the
sum of operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (per-shard shapes, so the per-device
traffic is collective_bytes / chips x a topology factor folded into the
link-bandwidth constant per the assignment).

MODEL_FLOPS = 6*N*D for training (2ND fwd + 4ND bwd) or 2*N_active*D for
serving; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute, MoE
dispatch waste, and masked-attention waste.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from .hlo_common import COLL_RE as _COLL_RE
from .hlo_common import shape_bytes as _shape_bytes

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / ICI link


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(4)
        ty = m.group(2) if m.group(2) is not None else m.group(3)
        b = _shape_bytes(ty or "")
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    bytes_per_device: float
    args_bytes_per_device: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-time / achievable step time (max of the three terms):
        the 'score' — how close the step is to the hardware roofline."""
        t_min = self.model_flops / (self.chips * PEAK_FLOPS)
        t_star = max(self.t_compute, self.t_memory, self.t_collective)
        return t_min / max(t_star, 1e-30)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def analyze(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    cost: Dict, hlo_text: str, model_flops: float, memstats=None,
) -> Roofline:
    """Prices the optimized per-device HLO with the trip-count-aware parser
    (hlo_cost.py) — XLA's own cost_analysis() counts loop bodies once and is
    kept only as a reference field. Whole-cluster totals = per-device * chips
    (SPMD: every device runs the same module)."""
    from . import hlo_cost

    c = hlo_cost.analyze_hlo(hlo_text)
    bpd = 0.0
    apd = 0.0
    if memstats is not None:
        bpd = float(
            getattr(memstats, "temp_size_in_bytes", 0)
            + getattr(memstats, "output_size_in_bytes", 0)
        )
        apd = float(getattr(memstats, "argument_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=c.flops * chips,
        hlo_bytes=c.bytes * chips,
        coll_bytes=c.coll_bytes * chips,
        coll_breakdown={k: int(v * chips) for k, v in c.coll.items()},
        model_flops=float(model_flops),
        bytes_per_device=bpd,
        args_bytes_per_device=apd,
    )


def model_flops_for(cfg, spec) -> float:
    """MODEL_FLOPS for a shape cell (6ND train / 2N_active D serve)."""
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_active * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec.global_batch


def save_report(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_json(), f, indent=2)
