"""Fault-tolerance runtime: preemption handling, straggler monitoring,
checkpoint/restart orchestration.

On a real multi-pod deployment each host runs this next to the train loop;
in this single-process container the same code paths drive the restart
integration tests (tests/test_fault.py) and the train CLI.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit flag.

    Usage:
      handler = PreemptionHandler(install=True)
      while training:
          ...
          if handler.should_stop: save_checkpoint(); break
    """

    def __init__(self, install: bool = True):
        self.should_stop = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handle)
                except ValueError:
                    pass  # not on main thread

    def _handle(self, signum, frame):
        self.should_stop = True

    def trigger(self):  # for tests
        self.should_stop = True


@dataclasses.dataclass
class StragglerMonitor:
    """Per-step wall-time EMA; flags steps slower than ``threshold`` x EMA.

    On a real pod the flag triggers the controller's slice-replacement /
    re-layout path; here it feeds telemetry + the restart policy. The EMA
    warms up for ``warmup`` steps before flagging.
    """

    threshold: float = 3.0
    decay: float = 0.9
    warmup: int = 10
    ema: float = 0.0
    count: int = 0
    flagged: int = 0
    _last: Optional[float] = None

    def start(self):
        self._last = time.monotonic()

    def stop(self) -> bool:
        """Record one step; returns True if this step was a straggler."""
        assert self._last is not None, "call start() first"
        dt = time.monotonic() - self._last
        self._last = None
        self.count += 1
        if self.count <= self.warmup:
            self.ema = dt if self.ema == 0.0 else (self.decay * self.ema + (1 - self.decay) * dt)
            return False
        is_straggler = dt > self.threshold * self.ema
        if is_straggler:
            self.flagged += 1
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return is_straggler


@dataclasses.dataclass
class RestartPolicy:
    """Bounded-retry restart loop for the training driver."""

    max_restarts: int = 3
    backoff_s: float = 1.0
    restarts: int = 0

    def run(self, fn: Callable[[], None], on_failure: Optional[Callable[[Exception], None]] = None):
        while True:
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — restart loop by design
                self.restarts += 1
                if on_failure is not None:
                    on_failure(e)
                if self.restarts > self.max_restarts:
                    raise
                time.sleep(self.backoff_s * (2 ** (self.restarts - 1)))
