"""Distribution substrate: sharding rules, grad compression, fault handling."""
from . import compression, fault, sharding  # noqa: F401
