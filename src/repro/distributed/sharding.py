"""Sharding rules: parameter-path patterns -> PartitionSpecs.

MaxText-style logical rules keyed on the stable parameter names produced by
the model zoo. Highlights:

* model axis ("model"): attention heads / MLA up-projections / FFN hidden /
  expert ffn dim / RG-LRU blocks / SSD heads / vocab.
* data axes ("pod","data"): batch; ZeRO-style sharding of analog tile state
  and digital optimizer moments (legal because analog updates are
  element-local — DESIGN.md §3).
* scan-stacked body params (path contains "/body/") get a leading None for
  the period axis.
* decode caches: batch dim on data axes when divisible, otherwise the
  sequence dim (long_500k batch=1 -> ring/sequence sharding).

All choices are divisibility-checked against the actual leaf shapes; a dim
that doesn't divide falls back to replication (GSPMD would pad, but uneven
pads on 512 ways waste memory).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.paths import path_str


def mesh_axis_sizes(mesh: Mesh):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_ax = "model" if "model" in mesh.axis_names else None
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    msize = mesh.shape[model_ax] if model_ax else 1
    return data_axes, dsize, model_ax, msize


# (regex, spec template) — templates use "M" for model, "D" for data axes,
# None for replicated; matched against the *trailing* dims of the leaf.
PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"embed$", ("M", None)),
    (r"head$", (None, "M")),
    (r"(wq|wk|wv|wuq|wuk|wuv)$", (None, "M")),
    (r"(bq|bk|bv)$", ("M",)),
    (r"attn/wo$", ("M", None)),
    (r"cross/wo$", ("M", None)),
    (r"(wdq|wdkv|wkr)$", (None, None)),
    (r"(qln|kvln|qn|kn|ln1|ln2|lnx|ln_f|norm)$", (None,)),
    (r"mlp/(wi|wg)$", (None, "M")),
    (r"mlp/wo$", ("M", None)),
    (r"moe/router$", (None, None)),
    (r"moe/(wi|wg)$", (None, None, "M")),
    (r"moe/wo$", (None, "M", None)),
    (r"moe/(swi|swg)$", (None, "M")),
    (r"moe/swo$", ("M", None)),
    (r"mix/(wx|wy|wz|wb|wc|wdt)$", (None, "M")),
    (r"mix/(war|wai)$", ("M", None, None)),
    (r"mix/lam$", ("M",)),
    (r"mix/(conv|conv_x|conv_b|conv_c)$", (None, "M")),
    (r"mix/(a_log|dt_bias|d_skip)$", ("M",)),
    (r"mix/wout$", ("M", None)),
    (r"wout$", ("M", None)),
    (r"(conv1|conv2)/w$", (None, None, None, None)),
    (r"/b$", (None,)),
    (r"/w$", (None, "M")),  # convnet fc fallback
)


def _resolve(template, shape, data_axes, dsize, model_ax, msize, zero_dim: Optional[int]):
    """Template -> PartitionSpec with divisibility checks. ``zero_dim`` marks
    the first replicated dim to ZeRO-shard over the data axes (or None)."""
    offset = len(shape) - len(template)
    spec: list = [None] * len(shape)
    for i, t in enumerate(template):
        dim = offset + i
        if t == "M" and model_ax and msize > 1 and shape[dim] % msize == 0 \
                and shape[dim] > 0:
            spec[dim] = model_ax
    if zero_dim is not None and data_axes and dsize > 1:
        for dim in range(len(shape)):
            if spec[dim] is None and shape[dim] % dsize == 0 and shape[dim] >= dsize:
                spec[dim] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
    return P(*spec)


def param_spec(path: str, shape, mesh: Mesh, zero: bool = False) -> P:
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    template = None
    for pat, tmpl in PARAM_RULES:
        if re.search(pat, path):
            template = tmpl
            break
    if template is None:
        template = (None,) * len(shape)
    if "/body/" in path and len(shape) > len(template):
        template = (None,) + tuple(template)
    while len(template) < len(shape):
        template = (None,) + tuple(template)
    template = tuple(template[-len(shape):]) if len(shape) else ()
    return _resolve(template, shape, data_axes, dsize, model_ax, msize,
                    0 if zero else None)


_TILE_SLOTS = r"(W|P|Qd|Qt|H|dev_p/(gamma|rho)|dev_w/(gamma|rho))"


def grouped_tile_spec(member_paths, shape, mesh: Mesh,
                      zero: bool = True) -> P:
    """PartitionSpec for a stacked tile-group array (n, *member-shape).

    Member dims inherit the owning weights' model-axis spec — but only when
    every member of the group agrees: tiles are grouped by (shape, dtype),
    so one stack can mix rules (attn/wq wants (None, "M") while same-shape
    attn/wo wants ("M", None)); a disagreeing group replicates its member
    dims rather than silently transposing half its tiles' layout. The
    leading stack axis is the natural ZeRO/scan axis (element-local updates,
    DESIGN.md §3) and takes the data axes when the group size divides,
    falling back to the first divisible replicated member dim otherwise.
    """
    if isinstance(member_paths, str):
        member_paths = (member_paths,)
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    specs = {param_spec(p, shape[1:], mesh) for p in member_paths}
    inner = specs.pop() if len(specs) == 1 else P(*([None] * (len(shape) - 1)))
    spec = [None] + list(inner) + [None] * (len(shape) - 1 - len(inner))
    if zero and data_axes and dsize > 1:
        daxes = data_axes if len(data_axes) > 1 else data_axes[0]
        if shape[0] % dsize == 0 and shape[0] >= dsize:
            spec[0] = daxes
        else:
            for dim in range(1, len(shape)):
                if spec[dim] is None and shape[dim] % dsize == 0 \
                        and shape[dim] >= dsize:
                    spec[dim] = daxes
                    break
    return P(*spec)


def state_shardings(state_tree, mesh: Mesh, zero_states: bool = True):
    """NamedShardings for an AnalogTrainer TrainState (abstract or concrete).

    Tile/optimizer arrays inherit the owning weight's spec plus ZeRO over the
    data axes; scalars replicate. Grouped (TileBank) states put the ZeRO axis
    on the leading stack dim (see grouped_tile_spec); legacy per-tile states
    keep the seed behaviour.
    """
    from repro.core.tile import TileBank

    bank = state_tree.get("tiles") if hasattr(state_tree, "get") else None
    members = dict(bank.index) if isinstance(bank, TileBank) else {}

    def spec_of(kp, leaf):
        path = path_str(kp)
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        # grouped layout: tiles/<group>/<slot>, leading stack axis
        m = re.match(rf"tiles/([^/]+)/{_TILE_SLOTS}$", path)
        if m and m.group(1) in members:
            return grouped_tile_spec(members[m.group(1)], shape, mesh,
                                     zero=zero_states)
        # grouped per-tile scalars stacked to (n,) / seeds (n, 2): replicate
        m = re.match(r"tiles/([^/]+)/(t|c|scale|prog|seed_w|seed_p)$", path)
        if m and m.group(1) in members:
            return P(*([None] * len(shape)))
        # legacy per-tile layout: tiles/<weight-path>/<slot>
        m = re.match(rf"tiles/(.*)/{_TILE_SLOTS}$", path)
        if m:
            return param_spec(m.group(1), shape, mesh, zero=zero_states)
        if path.startswith("opt/"):
            sub = re.sub(r"^opt/(mu|nu)/", "", path)
            return param_spec(sub, shape, mesh, zero=zero_states)
        if path.startswith("params/"):
            return param_spec(path[len("params/"):], shape, mesh)
        return param_spec(path, shape, mesh)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, spec_of(kp, leaf)), state_tree
    )


def params_shardings(params_tree, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh,
            param_spec(path_str(kp),
                       leaf.shape, mesh),
        ),
        params_tree,
    )


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(batch_tree, mesh: Mesh):
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    daxes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def spec_of(kp, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        spec: list = [None] * len(shape)
        if shape[0] % dsize == 0 and dsize > 1:
            spec[0] = daxes
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, spec_of(kp, leaf)), batch_tree
    )


def cache_shardings(cache_tree, mesh: Mesh):
    """Decode-cache shardings: batch on data axes when divisible, else the
    sequence dim (long-context batch=1); model axis on heads/head_dim/state
    dims when divisible."""
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    daxes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def spec_of(kp, leaf):
        path = path_str(kp)
        shape = leaf.shape
        name = path.split("/")[-1]
        spec: list = [None] * len(shape)
        if len(shape) == 0 or name == "pos":
            return P(*spec)
        # leading scan (period) axis for body caches
        bdim = 1 if "/body/" in path else 0
        if len(shape) <= bdim:
            return P(*spec)
        batch_ok = dsize > 1 and shape[bdim] % dsize == 0 and shape[bdim] >= dsize
        if batch_ok:
            spec[bdim] = daxes
        elif name in ("k", "v", "ckv", "kpe", "ck", "cv") and len(shape) > bdim + 1 \
                and dsize > 1 and shape[bdim + 1] % dsize == 0:
            spec[bdim + 1] = daxes  # shard cache sequence (long_500k)
        # model axis: try trailing dims (heads / head_dim / state dims)
        if model_ax:
            for dim in range(len(shape) - 1, bdim, -1):
                if spec[dim] is None and shape[dim] % msize == 0 and shape[dim] >= msize:
                    spec[dim] = model_ax
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, spec_of(kp, leaf)), cache_tree
    )


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def logical_rules(mesh: Mesh):
    """Table consumed by models.common.constrain()."""
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    daxes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    return mesh, {
        "batch": daxes,
        "embed": None,
        "heads": model_ax,
        "mlp": model_ax,
        "vocab": model_ax,
    }
