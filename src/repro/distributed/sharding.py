"""Sharding rules: parameter-path patterns -> PartitionSpecs.

MaxText-style logical rules keyed on the stable parameter names produced by
the model zoo. Highlights:

* model axis ("model"): attention heads / MLA up-projections / FFN hidden /
  expert ffn dim / RG-LRU blocks / SSD heads / vocab.
* data axes ("pod","data"): batch; ZeRO-style sharding of analog tile state
  and digital optimizer moments (legal because analog updates are
  element-local — DESIGN.md §3).
* scan-stacked body params (path contains "/body/") get a leading None for
  the period axis.
* decode caches: batch dim on data axes when divisible, otherwise the
  sequence dim (long_500k batch=1 -> ring/sequence sharding).

All choices are divisibility-checked against the actual leaf shapes; a dim
that doesn't divide falls back to replication (GSPMD would pad, but uneven
pads on 512 ways waste memory).
"""
from __future__ import annotations

import re
import warnings
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.paths import path_str

# jax >= 0.6 promotes shard_map to a top-level API; on 0.4.x the grouped
# tile update falls back to with_sharding_constraint + GSPMD (see
# shard_stacked_call).
_SHARD_MAP = getattr(jax, "shard_map", None)


def mesh_axis_sizes(mesh: Mesh):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_ax = "model" if "model" in mesh.axis_names else None
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    msize = mesh.shape[model_ax] if model_ax else 1
    return data_axes, dsize, model_ax, msize


# (regex, spec template) — templates use "M" for model, "D" for data axes,
# None for replicated; matched against the *trailing* dims of the leaf.
PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"embed$", ("M", None)),
    (r"head$", (None, "M")),
    (r"(wq|wk|wv|wuq|wuk|wuv)$", (None, "M")),
    (r"(bq|bk|bv)$", ("M",)),
    (r"attn/wo$", ("M", None)),
    (r"cross/wo$", ("M", None)),
    (r"(wdq|wdkv|wkr)$", (None, None)),
    (r"(qln|kvln|qn|kn|ln1|ln2|lnx|ln_f|norm)$", (None,)),
    (r"mlp/(wi|wg)$", (None, "M")),
    (r"mlp/wo$", ("M", None)),
    (r"moe/router$", (None, None)),
    (r"moe/(wi|wg)$", (None, None, "M")),
    (r"moe/wo$", (None, "M", None)),
    (r"moe/(swi|swg)$", (None, "M")),
    (r"moe/swo$", ("M", None)),
    (r"mix/(wx|wy|wz|wb|wc|wdt)$", (None, "M")),
    (r"mix/(war|wai)$", ("M", None, None)),
    (r"mix/lam$", ("M",)),
    (r"mix/(conv|conv_x|conv_b|conv_c)$", (None, "M")),
    (r"mix/(a_log|dt_bias|d_skip)$", ("M",)),
    (r"mix/wout$", ("M", None)),
    (r"wout$", ("M", None)),
    (r"(conv1|conv2)/w$", (None, None, None, None)),
    (r"/b$", (None,)),
    (r"/w$", (None, "M")),  # convnet fc fallback
)


def _resolve(template, shape, data_axes, dsize, model_ax, msize, zero_dim: Optional[int]):
    """Template -> PartitionSpec with divisibility checks. ``zero_dim`` marks
    the first replicated dim to ZeRO-shard over the data axes (or None)."""
    offset = len(shape) - len(template)
    spec: list = [None] * len(shape)
    for i, t in enumerate(template):
        dim = offset + i
        if t == "M" and model_ax and msize > 1 and shape[dim] % msize == 0 \
                and shape[dim] > 0:
            spec[dim] = model_ax
    if zero_dim is not None and data_axes and dsize > 1:
        for dim in range(len(shape)):
            if spec[dim] is None and shape[dim] % dsize == 0 and shape[dim] >= dsize:
                spec[dim] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
    return P(*spec)


def rule_template(path: str, ndim: int) -> Tuple:
    """Mesh-independent spec template of a parameter path, normalized to
    ``ndim`` dims (leading dims pad with None; body-scan params gain a
    leading None for the period axis). This is the rule identity used for
    spec-aware tile grouping: two paths with equal templates shard
    identically on every mesh, so their tiles may share a stack."""
    template = None
    for pat, tmpl in PARAM_RULES:
        if re.search(pat, path):
            template = tmpl
            break
    if template is None:
        template = (None,) * ndim
    if "/body/" in path and ndim > len(template):
        template = (None,) + tuple(template)
    while len(template) < ndim:
        template = (None,) + tuple(template)
    return tuple(template[-ndim:]) if ndim else ()


def template_tag(template) -> str:
    """Short stable name of a rule template, used inside tile-group keys:
    (None, "M") -> "nM", ("M", None, None) -> "Mnn", () -> "s" (scalar)."""
    if not template:
        return "s"
    return "".join({"M": "M", "D": "D"}.get(t, "n") for t in template)


def param_spec(path: str, shape, mesh: Mesh, zero: bool = False) -> P:
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    template = rule_template(path, len(shape))
    return _resolve(template, shape, data_axes, dsize, model_ax, msize,
                    0 if zero else None)


_TILE_SLOTS = r"(W|P|Qd|Qt|H|dev_p/(gamma|rho)|dev_w/(gamma|rho))"

# group signatures already warned about (one warning per offending stack)
_MIXED_RULE_WARNED: set = set()


def grouped_tile_spec(member_paths, shape, mesh: Mesh,
                      zero: bool = True) -> P:
    """PartitionSpec for a stacked tile-group array (n, *member-shape).

    Member dims inherit the owning weights' model-axis spec. Groups key on
    (shape, dtype, rule template) — see ``repro.core.tile.group_tiles`` — so
    every member of a stack resolves to the same spec and the member dims
    can always carry the model axis. A stack that nonetheless mixes rules
    (hand-built banks, or pre-spec-aware legacy groups) replicates its
    member dims rather than silently transposing half its tiles' layout,
    and warns once naming the offending paths. The leading stack axis is
    the natural ZeRO/scan axis (element-local updates, DESIGN.md §3) and
    takes the data axes when the group size divides, falling back to the
    first divisible replicated member dim otherwise.
    """
    if isinstance(member_paths, str):
        member_paths = (member_paths,)
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    per_path = {p: param_spec(p, shape[1:], mesh) for p in member_paths}
    specs = set(per_path.values())
    if len(specs) == 1:
        inner = specs.pop()
    else:
        inner = P(*([None] * (len(shape) - 1)))
        sig = tuple(sorted(member_paths))
        if sig not in _MIXED_RULE_WARNED:
            _MIXED_RULE_WARNED.add(sig)
            warnings.warn(
                "tile group mixes partition rules; model axis dropped "
                "(member dims replicate) for stack of "
                + ", ".join(f"{p}->{per_path[p]}" for p in sig)
                + " — re-group with spec-aware keys (core.tile.group_tiles)",
                stacklevel=2)
    spec = [None] + list(inner) + [None] * (len(shape) - 1 - len(inner))
    if zero and data_axes and dsize > 1:
        daxes = data_axes if len(data_axes) > 1 else data_axes[0]
        if shape[0] % dsize == 0 and shape[0] >= dsize:
            spec[0] = daxes
        else:
            for dim in range(1, len(shape)):
                if spec[dim] is None and shape[dim] % dsize == 0 \
                        and shape[dim] >= dsize:
                    spec[dim] = daxes
                    break
    return P(*spec)


def merge_specs(specs):
    """Dim-wise agreement of PartitionSpecs: keep an axis only where every
    spec places it; disagreeing dims replicate. Used to constrain a scan
    stack of same-structure groups whose member rules differ."""
    specs = [tuple(s) for s in specs]
    n = max((len(s) for s in specs), default=0)
    specs = [s + (None,) * (n - len(s)) for s in specs]
    return P(*[s0 if all(s[d] == s0 for s in specs) else None
               for d, s0 in enumerate(specs[0])]) if specs else P()


def constrain_stacked(tree, member_paths, mesh: Mesh, zero: bool = True,
                      prefix: int = 0):
    """with_sharding_constraint over every stacked tile-state leaf of
    ``tree`` (a stacked TileState, a stacked gradient array, or any pytree
    of (n, *member-shape) arrays).

    Leaves of rank >= prefix + 3 (``prefix`` extra leading axes — the scan
    class axis — then stack axis + a >=2-D member weight) get the group
    spec from ``grouped_tile_spec``; per-tile scalars (n,) and seeds (n, 2)
    pin to replicated, matching ``state_shardings`` so a donated train_step
    round-trips without resharding. ``member_paths`` may be a tuple of path
    tuples, one per scanned group — the constraint is then the dim-wise
    agreement of the groups' specs (merge_specs).
    """
    paths_list = [member_paths] if member_paths and isinstance(
        member_paths[0], str) else list(member_paths)

    def c(leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd < prefix + 3:
            spec = P(*([None] * nd))
        else:
            inner = merge_specs([
                grouped_tile_spec(ps, leaf.shape[prefix:], mesh, zero=zero)
                for ps in paths_list])
            spec = P(*([None] * prefix + list(inner)))
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree.map(c, tree)


def shard_stacked_call(fn, mesh: Mesh, n: int, *args):
    """Run ``fn(*args)`` with every argument/output's leading axis (length
    ``n``) sharded over the data axes, as a manual map.

    ``fn`` must be element-local over axis 0 — true of every stacked tile
    phase (begin_step / update vmapped over the stack): tile updates touch
    only their own elements, so the shard_map needs no collectives and is
    bit-identical to the global call. Requires jax >= 0.6 (top-level
    jax.shard_map) and n divisible by the data-axes size; returns None
    otherwise and the caller falls back to with_sharding_constraint +
    GSPMD, which is the only path on jax 0.4.x.
    """
    data_axes, dsize, _, _ = mesh_axis_sizes(mesh)
    if _SHARD_MAP is None or dsize <= 1 or n % dsize:
        return None
    daxes = data_axes if len(data_axes) > 1 else data_axes[0]

    def spec_of(leaf):
        return P(daxes, *([None] * (getattr(leaf, "ndim", 1) - 1)))

    in_specs = jax.tree.map(spec_of, args)
    out_specs = jax.tree.map(spec_of, jax.eval_shape(fn, *args))
    return _SHARD_MAP(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs)(*args)


def state_shardings(state_tree, mesh: Mesh, zero_states: bool = True):
    """NamedShardings for an AnalogTrainer TrainState (abstract or concrete).

    Tile/optimizer arrays inherit the owning weight's spec plus ZeRO over the
    data axes; scalars replicate. Class-keyed (TileBank) states carry
    (C, n, *member) leaves: the class axis replicates (it is the scan axis),
    the stack axis takes the ZeRO/data axes and the member dims the
    dim-wise agreement of the member groups' model-axis specs — exactly the
    spec ``constrain_stacked(prefix=1)`` pins inside the step, so a donated
    train_step round-trips without resharding. Legacy per-tile states keep
    the old behaviour.
    """
    from repro.core.tile import TileBank

    bank = state_tree.get("tiles") if hasattr(state_tree, "get") else None
    members = dict(bank.index) if isinstance(bank, TileBank) else {}
    class_groups = dict(bank.class_index) if isinstance(bank, TileBank) else {}

    def spec_of(kp, leaf):
        path = path_str(kp)
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        # class-keyed layout: tiles/<class>/<slot>, (C, n, *member) leaves
        m = re.match(rf"tiles/([^/]+)/{_TILE_SLOTS}$", path)
        if m and m.group(1) in class_groups:
            inner = merge_specs([
                grouped_tile_spec(members[g], shape[1:], mesh,
                                  zero=zero_states)
                for g in class_groups[m.group(1)]])
            return P(None, *inner)
        # per-group layout (hand-built (n, *member) stacks): stack axis leads
        if m and m.group(1) in members:
            return grouped_tile_spec(members[m.group(1)], shape, mesh,
                                     zero=zero_states)
        # stacked per-tile scalars (C, n) / seeds (C, n, 2): replicate
        m = re.match(r"tiles/([^/]+)/(t|c|scale|prog|seed_w|seed_p)$", path)
        if m and (m.group(1) in class_groups or m.group(1) in members):
            return P(*([None] * len(shape)))
        # legacy per-tile layout: tiles/<weight-path>/<slot>
        m = re.match(rf"tiles/(.*)/{_TILE_SLOTS}$", path)
        if m:
            return param_spec(m.group(1), shape, mesh, zero=zero_states)
        if path.startswith("opt/"):
            sub = re.sub(r"^opt/(mu|nu)/", "", path)
            return param_spec(sub, shape, mesh, zero=zero_states)
        if path.startswith("params/"):
            return param_spec(path[len("params/"):], shape, mesh)
        return param_spec(path, shape, mesh)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, spec_of(kp, leaf)), state_tree
    )


def params_shardings(params_tree, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh,
            param_spec(path_str(kp),
                       leaf.shape, mesh),
        ),
        params_tree,
    )


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(batch_tree, mesh: Mesh):
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    daxes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def spec_of(kp, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        spec: list = [None] * len(shape)
        if shape[0] % dsize == 0 and dsize > 1:
            spec[0] = daxes
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, spec_of(kp, leaf)), batch_tree
    )


def cache_shardings(cache_tree, mesh: Mesh):
    """Decode-cache shardings: batch on data axes when divisible, else the
    sequence dim (long-context batch=1); model axis on heads/head_dim/state
    dims when divisible."""
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    daxes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def spec_of(kp, leaf):
        path = path_str(kp)
        shape = leaf.shape
        name = path.split("/")[-1]
        spec: list = [None] * len(shape)
        if len(shape) == 0 or name == "pos":
            return P(*spec)
        # leading scan (period) axis for body caches
        bdim = 1 if "/body/" in path else 0
        if len(shape) <= bdim:
            return P(*spec)
        batch_ok = dsize > 1 and shape[bdim] % dsize == 0 and shape[bdim] >= dsize
        if batch_ok:
            spec[bdim] = daxes
        elif name in ("k", "v", "ckv", "kpe", "ck", "cv") and len(shape) > bdim + 1 \
                and dsize > 1 and shape[bdim + 1] % dsize == 0:
            spec[bdim + 1] = daxes  # shard cache sequence (long_500k)
        # model axis: try trailing dims (heads / head_dim / state dims)
        if model_ax:
            for dim in range(len(shape) - 1, bdim, -1):
                if spec[dim] is None and shape[dim] % msize == 0 and shape[dim] >= msize:
                    spec[dim] = model_ax
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(mesh, spec_of(kp, leaf)), cache_tree
    )


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def logical_rules(mesh: Mesh):
    """Table consumed by models.common.constrain()."""
    data_axes, dsize, model_ax, msize = mesh_axis_sizes(mesh)
    daxes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    return mesh, {
        "batch": daxes,
        "embed": None,
        "heads": model_ax,
        "mlp": model_ax,
        "vocab": model_ax,
    }
