"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized all-reduce with error feedback: each DP worker
quantizes its local gradient shard to int8 with per-block f32 scales,
all-reduces the quantized payload (4x less ICI traffic than f32, 2x less
than bf16), dequantizes, and accumulates the quantization residual into a
local error-feedback buffer added to the next step's gradient. With error
feedback the compressed SGD trajectory converges to the uncompressed one
(Karimireddy et al. 2019) — verified in tests/test_compression.py.

Implemented as a shard_map collective so it composes with the jit train
step; this is one of the §Perf levers for collective-bound cells.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

BLOCK = 256


def quantize_int8(x, block: int = BLOCK):
    """x: (N,) f32 -> (q int8 (N,), scales f32 (N/block,))."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0], n


def dequantize_int8(q, scale, n, block: int = BLOCK):
    xq = q.reshape(-1, block).astype(jnp.float32) * scale[:, None]
    return xq.reshape(-1)[:n]


def compressed_psum_mean(x, axis_name: str):
    """int8 all-reduce-mean of ``x`` over ``axis_name`` (inside shard_map).

    Per-worker scales can't be summed directly, so the scheme synchronizes a
    per-block max scale first (a tiny f32 payload), quantizes every worker's
    contribution with the shared scale, and psums the int8 payload in int32.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    blk = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    local_scale = jnp.maximum(jnp.max(jnp.abs(blk), axis=1) / 127.0, 1e-12)
    gmax = jax.lax.pmax(local_scale, axis_name)                     # (nblk,)
    q = jnp.clip(jnp.round(blk / gmax[:, None]), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    nworkers = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = qsum.astype(jnp.float32) * gmax[:, None] / nworkers.astype(jnp.float32)
    out = mean.reshape(-1)[:n]
    # error feedback: what quantization dropped from *this worker's* share
    err = flat - (q.astype(jnp.float32) * gmax[:, None]).reshape(-1)[:n]
    return out.reshape(x.shape).astype(x.dtype), err.reshape(x.shape)


def make_compressed_grad_fn(mesh: Mesh, axis_name: str = "data"):
    """Returns f(local_grad, err_buf) -> (mean_grad, new_err_buf) running the
    int8 all-reduce via shard_map over ``axis_name`` (grad replicated on the
    other axes)."""

    def _inner(g, err):
        g = g + err  # error feedback
        mean, new_err = compressed_psum_mean(g, axis_name)
        return mean, new_err

    def apply(local_grad, err_buf):
        fn = _shard_map(
            _inner,
            mesh=mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(axis_name), P(axis_name)),
        )
        return fn(local_grad, err_buf)

    return apply
