"""Attention variants: GQA (+qk-norm/bias), sliding-window, MLA, cross-attn.

Full-sequence paths (train/prefill) use a chunked memory-efficient attention
core (online softmax over KV chunks via lax.scan) so that 32k-prefill and
4k-train lower with O(S * chunk) live attention memory instead of O(S^2).

Decode paths attend a single query over the cache; MLA decodes in the
*weight-absorbed* latent form (scores and values computed directly against
the compressed c_kv cache — the deployment-efficient form).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .common import (apply_mrope, apply_rope, constrain,
                     constrain_attention_q, dense_init, rms_norm)

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, H, KV, D = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * D), cfg.dtype),
        "wk": dense_init(ks[1], (d, KV * D), cfg.dtype),
        "wv": dense_init(ks[2], (d, KV * D), cfg.dtype),
        "wo": dense_init(ks[3], (H * D, d), cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * D,), cfg.dtype)
        p["bk"] = jnp.zeros((KV * D,), cfg.dtype)
        p["bv"] = jnp.zeros((KV * D,), cfg.dtype)
    if cfg.qk_norm and not cross:
        p["qn"] = jnp.zeros((D,), cfg.dtype)
        p["kn"] = jnp.zeros((D,), cfg.dtype)
    return p


def init_mla(key, cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope, v, ql, kvl = cfg.qk_nope, cfg.qk_rope, cfg.v_head_dim, cfg.q_lora, cfg.kv_lora
    ks = jax.random.split(key, 7)
    return {
        "wdq": dense_init(ks[0], (d, ql), cfg.dtype),
        "qln": jnp.zeros((ql,), cfg.dtype),
        "wuq": dense_init(ks[1], (ql, H * (nope + rope)), cfg.dtype),
        "wdkv": dense_init(ks[2], (d, kvl), cfg.dtype),
        "kvln": jnp.zeros((kvl,), cfg.dtype),
        "wuk": dense_init(ks[3], (kvl, H * nope), cfg.dtype),
        "wuv": dense_init(ks[4], (kvl, H * v), cfg.dtype),
        "wkr": dense_init(ks[5], (d, rope), cfg.dtype),
        "wo": dense_init(ks[6], (H * v, d), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# chunked memory-efficient attention core
# ---------------------------------------------------------------------------


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      chunk: int = 1024, q_offset: int = 0):
    """Memory-efficient attention with a FlashAttention-style custom VJP.

    Forward: online softmax over KV chunks (O(Sq*chunk) live scores).
    Backward: recomputes the probabilities per chunk from (q,k,v,lse) —
    without this, autodiff through the scan would save O(Sq*Sk) residuals
    and train_4k/prefill_32k could not fit HBM.
    """
    return _flash(q, k, v, causal, window, min(chunk, k.shape[1]), q_offset)


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, chunk, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out


def _masked_scores(qg, kb, ci, chunk, Sk, Sq, causal, window, q_offset):
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                   preferred_element_type=jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = ci * chunk + jnp.arange(chunk)
    mask = k_pos[None, :] < Sk
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if window and window > 0:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    return jnp.where(mask[None, :, None, None, :], s, NEG_INF)


def _flash_chunks(k, v, chunk):
    B, Sk, KV, Dk = k.shape
    Dv = v.shape[-1]
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (Sk + pad) // chunk
    return (k.reshape(B, n, chunk, KV, Dk).swapaxes(0, 1),
            v.reshape(B, n, chunk, KV, Dv).swapaxes(0, 1), n)


def _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset):
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    # keep q in its storage dtype (bf16 at LM scale): the MXU takes bf16
    # operands with f32 accumulation, and every all-gather/psum of the
    # attention activations moves half the bytes vs a f32 pre-cast
    qg = (q * jnp.asarray(Dk ** -0.5, q.dtype)).reshape(B, Sq, KV, G, Dk)
    kc, vc, n_chunks = _flash_chunks(k, v, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = _masked_scores(qg, kb, ci, chunk, Sk, Sq, causal, window, q_offset)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(n_chunks)))
    l_safe = jnp.maximum(l, 1e-37)
    out = (acc / l_safe[..., None]).reshape(B, Sq, H, Dv).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_vjp_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, chunk, q_offset, res, do):
    q, k, v, out, lse = res
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    scale = Dk ** -0.5
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, KV, G, Dk)
    dog = do.reshape(B, Sq, KV, G, Dv)
    outg = out.reshape(B, Sq, KV, G, Dv)
    delta = jnp.sum(dog.astype(jnp.float32) * outg.astype(jnp.float32),
                    axis=-1)                                 # (B,Sq,KV,G)
    kc, vc, n_chunks = _flash_chunks(k, v, chunk)

    def body(dq, xs):
        kb, vb, ci = xs
        s = _masked_scores(qg, kb, ci, chunk, Sk, Sq, causal, window, q_offset)
        p = jnp.exp(s - lse[..., None])                      # (B,Sq,KV,G,c)
        pb = p.astype(vb.dtype)
        dv_c = jnp.einsum("bqkgc,bqkgd->bckd", pb, dog,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqkgd,bckd->bqkgc", dog, vb,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None])).astype(kb.dtype)
        dq = dq + jnp.einsum("bqkgc,bckd->bqkgd", ds, kb,
                             preferred_element_type=jnp.float32) * scale
        dk_c = jnp.einsum("bqkgc,bqkgd->bckd", ds, qg,
                          preferred_element_type=jnp.float32)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, KV, G, Dk), jnp.float32)
    dq, (dkc, dvc) = jax.lax.scan(body, dq0, (kc, vc, jnp.arange(n_chunks)))
    dk = dkc.swapaxes(0, 1).reshape(B, n_chunks * chunk, KV, Dk)[:, :Sk]
    dv = dvc.swapaxes(0, 1).reshape(B, n_chunks * chunk, KV, Dv)[:, :Sk]
    return (dq.reshape(B, Sq, H, Dk).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _chunked_attention_reference(
    q,          # (B, Sq, H, Dk)
    k,          # (B, Sk, KV, Dk)
    v,          # (B, Sk, KV, Dv)
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
):
    """Plain (non-custom-vjp) online-softmax reference used in tests."""
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sk + pad) // chunk

    qg = (q.astype(jnp.float32) * (Dk ** -0.5)).reshape(B, Sq, KV, G, Dk)
    kc = k.reshape(B, n_chunks, chunk, KV, Dk).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, KV, Dv).swapaxes(0, 1)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < Sk
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window and window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention(q, k, v, *, k_pos, pos, window: int = 0):
    """Single-token attention over a cache.

    q: (B, 1, H, Dk); k/v: (B, Sc, KV, D*); k_pos: (Sc,) stored absolute
    positions (-1 = empty slot); pos: scalar current position.
    """
    B, _, H, Dk = q.shape
    _, Sc, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    qg = (q.astype(jnp.float32) * (Dk ** -0.5)).reshape(B, KV, G, Dk)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    valid = (k_pos >= 0) & (k_pos <= pos)
    if window and window > 0:
        valid = valid & (k_pos > pos - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


def decode_attention_lanes(q, k, v, *, k_pos, pos, window: int = 0):
    """Single-token attention where every lane sits at its own position.

    Same math as ``decode_attention`` but with per-lane masking, for the
    continuous-batching serve engine: k_pos is (B, Sc) logical positions
    per lane (-1 = empty slot) and pos is (B,) the position each lane is
    writing this step.
    """
    B, _, H, Dk = q.shape
    _, Sc, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    qg = (q.astype(jnp.float32) * (Dk ** -0.5)).reshape(B, KV, G, Dk)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])
    if window and window > 0:
        valid = valid & (k_pos > (pos[:, None] - window))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA full-sequence + decode
# ---------------------------------------------------------------------------


def _qkv(p, x, cfg: ModelConfig):
    B, S, d = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, KV, D)
    v = v.reshape(B, S, KV, D)
    if "qn" in p:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    return q, k, v


def _rope_qk(q, k, positions, cfg: ModelConfig):
    if cfg.rope_type == "mrope":
        if positions.ndim == q.ndim - 1:  # (B,S) text-only -> same pos 3x
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_base)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_base)
    else:
        q = apply_rope(q, positions, cfg.rope_base)
        k = apply_rope(k, positions, cfg.rope_base)
    return q, k


def attn_forward(p, x, cfg: ModelConfig, *, kind: str, positions, causal=True):
    """Full-sequence self-attention ('attn' | 'attn_local')."""
    q, k, v = _qkv(p, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    q = constrain_attention_q(q)
    window = cfg.window if kind == "attn_local" else 0
    out = chunked_attention(q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    return constrain(out, "batch", None, "embed")


def attn_prefill(p, x, cfg: ModelConfig, *, kind: str, positions, cache):
    """Full-sequence forward that also fills the KV cache."""
    q, k, v = _qkv(p, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    window = cfg.window if kind == "attn_local" else 0
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=cfg.attn_chunk)
    out = out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    S = x.shape[1]
    Sc = cache["k"].shape[1]
    if Sc >= S:
        newk = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        newv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        kpos = jax.lax.dynamic_update_slice(cache["pos"], jnp.arange(S, dtype=jnp.int32), (0,))
    else:  # ring buffer smaller than prompt: keep the last Sc positions
        newk = k[:, S - Sc:].astype(cache["k"].dtype)
        newv = v[:, S - Sc:].astype(cache["v"].dtype)
        kpos = jnp.arange(S - Sc, S, dtype=jnp.int32)
        # ring order: slot = pos % Sc
        perm = jnp.argsort(kpos % Sc)
        newk = newk[:, perm]
        newv = newv[:, perm]
        kpos = kpos[perm]
    cache = dict(cache, k=newk, v=newv, pos=kpos)
    return out, cache


def attn_decode(p, x, cfg: ModelConfig, *, kind: str, pos, cache):
    """One-token decode. cache: {'k','v': (B,Sc,KV,D), 'pos': (Sc,)}."""
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    posb = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q, k = _rope_qk(q, k, posb, cfg)
    Sc = cache["k"].shape[1]
    slot = pos % Sc  # ring when local; Sc >= S_max when global
    newk = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    newv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (slot,))
    window = cfg.window if kind == "attn_local" else 0
    out = decode_attention(q, newk, newv, k_pos=kpos, pos=pos, window=window)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, dict(cache, k=newk, v=newv, pos=kpos)


def make_attn_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int, abstract=False):
    Sc = min(cfg.window, seq_len) if (kind == "attn_local" and cfg.window) else seq_len
    KV, D = cfg.n_kv, cfg.head_dim
    shapes = {
        "k": ((batch, Sc, KV, D), cfg.dtype),
        "v": ((batch, Sc, KV, D), cfg.dtype),
        "pos": ((Sc,), jnp.int32),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(s, dt) for n, (s, dt) in shapes.items()}
    c = {n: jnp.zeros(s, dt) for n, (s, dt) in shapes.items()}
    c["pos"] = jnp.full((Sc,), -1, jnp.int32)
    return c


# ---------------------------------------------------------------------------
# paged KV cache (continuous-batching serve engine)
# ---------------------------------------------------------------------------
#
# The pool holds ``num_pages`` fixed-size pages shared by every lane; a page
# table row (per lane) maps logical slot j -> physical slot
# table[j // page_size] * page_size + j % page_size.  Page 0 is reserved as a
# scratch page: free lanes point their whole table row at it, so their decode
# writes land in storage no active lane ever gathers.


def make_paged_attn_cache(cfg: ModelConfig, num_pages: int, page_size: int, abstract=False):
    KV, D = cfg.n_kv, cfg.head_dim
    shapes = {
        "kp": ((num_pages, page_size, KV, D), cfg.dtype),
        "vp": ((num_pages, page_size, KV, D), cfg.dtype),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(s, dt) for n, (s, dt) in shapes.items()}
    return {n: jnp.zeros(s, dt) for n, (s, dt) in shapes.items()}


def attn_decode_paged(p, x, cfg: ModelConfig, *, kind: str, pos, table, cache):
    """One-token decode against a paged KV pool.

    x: (B,1,d); pos: (B,) per-lane write position; table: (B,T) page table;
    cache: {'kp','vp': (P, page_size, KV, D)} shared pools.  Writes this
    token's K/V into each lane's page slot, then gathers the lane's pages
    back into a (B, T*page_size, KV, D) view for ``decode_attention_lanes``.
    """
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    q, k = _rope_qk(q, k, pos[:, None], cfg)
    P, ps = cache["kp"].shape[0], cache["kp"].shape[1]
    kflat = cache["kp"].reshape(P * ps, *cache["kp"].shape[2:])
    vflat = cache["vp"].reshape(P * ps, *cache["vp"].shape[2:])
    # scatter: free lanes all collide on the scratch page — harmless
    widx = table[jnp.arange(B), pos // ps] * ps + pos % ps
    kflat = kflat.at[widx].set(k[:, 0].astype(kflat.dtype))
    vflat = vflat.at[widx].set(v[:, 0].astype(vflat.dtype))
    # gather every lane's pages into a contiguous logical view
    T = table.shape[1]
    gidx = (table[:, :, None] * ps + jnp.arange(ps)[None, None, :]).reshape(B, T * ps)
    kl, vl = kflat[gidx], vflat[gidx]
    k_pos = jnp.broadcast_to(jnp.arange(T * ps, dtype=jnp.int32)[None], (B, T * ps))
    window = cfg.window if kind == "attn_local" else 0
    out = decode_attention_lanes(q, kl, vl, k_pos=k_pos, pos=pos, window=window)
    out = out.reshape(B, 1, -1) @ p["wo"]
    return out, dict(cache, kp=kflat.reshape(cache["kp"].shape),
                     vp=vflat.reshape(cache["vp"].shape))


def _chunk_attention(q, k, v, *, q_pos, k_pos, window: int = 0,
                     chunk: int = 1024):
    """Online-softmax attention with *dynamic* per-row masks, mirroring
    ``_flash_fwd_impl`` update-for-update (same m/l/acc recurrence, same
    einsums, same dtype handling).  Masked keys contribute exactly zero
    (``exp(NEG_INF - m) == 0``), so over any key set whose valid subset
    matches the dense path's, a single-chunk lowering reproduces the dense
    flash forward — the identity the batched/chunked serve prefill rides.

    q: (B,Sq,H,Dk); k/v: (B,Sk,KV,D*); q_pos: (B,Sq) absolute positions of
    the queries; k_pos: (B,Sk) stored positions (-1 = empty/stale slot).
    """
    B, Sq, H, Dk = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    chunk = min(chunk, Sk)
    qg = (q * jnp.asarray(Dk ** -0.5, q.dtype)).reshape(B, Sq, KV, G, Dk)
    kc, vc, n_chunks = _flash_chunks(k, v, chunk)
    pad = (-Sk) % chunk
    kpp = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1) if pad else k_pos
    kpc = kpp.reshape(B, n_chunks, chunk).swapaxes(0, 1)          # (n,B,chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, kp = xs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32)
        mask = (kp[:, None, :] >= 0) & (kp[:, None, :] <= q_pos[:, :, None])
        if window and window > 0:
            mask = mask & (kp[:, None, :] > q_pos[:, :, None] - window)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kpc))
    l_safe = jnp.maximum(l, 1e-37)
    return (acc / l_safe[..., None]).reshape(B, Sq, H, Dv).astype(q.dtype)


def attn_chunk_paged(p, x, cfg: ModelConfig, *, kind: str, positions, lengths,
                     table, cache):
    """Batched bucketed/chunked prefill straight into the paged KV pools.

    x: (B,Cb,d) right-padded chunk batch; positions: (B,Cb) absolute
    positions (``start + j``); lengths: (B,) valid run per row; table: (B,T)
    page-table rows.  Scatters the chunk's K/V into each row's pages first
    (padded slots land on the scratch page), then attends the chunk queries
    over the row's *gathered* logical view — earlier chunks and shared
    prefix pages included — under a ``k_pos <= q_pos`` mask, so one jitted
    signature serves plain bucketed prefill, chunk continuation, and
    prefix-shared tails alike.
    """
    B, Cb, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    P, ps = cache["kp"].shape[0], cache["kp"].shape[1]
    kflat = cache["kp"].reshape(P * ps, *cache["kp"].shape[2:])
    vflat = cache["vp"].reshape(P * ps, *cache["vp"].shape[2:])
    valid = jnp.arange(Cb, dtype=jnp.int32)[None, :] < lengths[:, None]
    page_of = jnp.take_along_axis(table, positions // ps, axis=1)  # (B,Cb)
    widx = jnp.where(valid, page_of * ps + positions % ps, 0).reshape(-1)
    kflat = kflat.at[widx].set(k.reshape(B * Cb, *k.shape[2:]).astype(kflat.dtype))
    vflat = vflat.at[widx].set(v.reshape(B * Cb, *v.shape[2:]).astype(vflat.dtype))
    T = table.shape[1]
    gidx = (table[:, :, None] * ps + jnp.arange(ps)[None, None, :]).reshape(B, T * ps)
    kl, vl = kflat[gidx], vflat[gidx]
    k_pos = jnp.broadcast_to(jnp.arange(T * ps, dtype=jnp.int32)[None], (B, T * ps))
    window = cfg.window if kind == "attn_local" else 0
    out = _chunk_attention(q, kl, vl, q_pos=positions, k_pos=k_pos,
                           window=window, chunk=cfg.attn_chunk)
    out = out.reshape(B, Cb, -1) @ p["wo"]
    return out, dict(cache, kp=kflat.reshape(cache["kp"].shape),
                     vp=vflat.reshape(cache["vp"].shape))


def commit_prefill_pages(cache, dense, idx, *, stacked: bool):
    """Scatter a batch-1 dense prefill cache {'k','v','pos'} into the paged
    pools.  ``idx`` (S,) maps logical position j to its flat physical slot
    (page-table row expanded); the dense 'pos' leaf routes ring-ordered
    sliding-window caches (slot order != logical order, invalid slots = -1,
    which land on the scratch page).  ``stacked`` marks body leaves carrying
    a leading scan (period) axis — every period layer saw the same positions,
    so one routing row serves the whole stack."""
    kp, vp = cache["kp"], cache["vp"]
    pos_leaf = dense["pos"][0] if stacked else dense["pos"]   # (Sc,)
    valid = pos_leaf >= 0
    tgt = jnp.where(valid, idx[jnp.clip(pos_leaf, 0)], 0)
    if stacked:
        n, P, ps = kp.shape[0], kp.shape[1], kp.shape[2]
        kflat = kp.reshape(n, P * ps, *kp.shape[3:]).at[:, tgt].set(
            dense["k"][:, 0].astype(kp.dtype))
        vflat = vp.reshape(n, P * ps, *vp.shape[3:]).at[:, tgt].set(
            dense["v"][:, 0].astype(vp.dtype))
    else:
        P, ps = kp.shape[0], kp.shape[1]
        kflat = kp.reshape(P * ps, *kp.shape[2:]).at[tgt].set(
            dense["k"][0].astype(kp.dtype))
        vflat = vp.reshape(P * ps, *vp.shape[2:]).at[tgt].set(
            dense["v"][0].astype(vp.dtype))
    return dict(cache, kp=kflat.reshape(kp.shape), vp=vflat.reshape(vp.shape))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


def _mla_q(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, nope, rope = cfg.n_heads, cfg.qk_nope, cfg.qk_rope
    ql = rms_norm(x @ p["wdq"], p["qln"], cfg.norm_eps)
    q = (ql @ p["wuq"]).reshape(B, S, H, nope + rope)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_base)
    return q_nope, q_pe


def _mla_kv_latent(p, x, cfg: ModelConfig, positions):
    ckv = rms_norm(x @ p["wdkv"], p["kvln"], cfg.norm_eps)  # (B,S,kvl)
    k_pe = apply_rope((x @ p["wkr"])[:, :, None, :], positions, cfg.rope_base)[:, :, 0]
    return ckv, k_pe


def mla_forward(p, x, cfg: ModelConfig, *, positions, causal=True):
    """Train/prefill MLA.

    Two lowerings of the same math:
      expanded — materializes per-head K/V from the latent (HF-style);
                 K-side traffic H*(nope+rope+v) per token.
      absorbed — attends directly against the shared latent (c_kv ++ k_pe,
                 KV=1): K-side traffic (kv_lora+rope) per token — ~20x less
                 HBM movement for ~(kv_lora/nope)x more score FLOPs. The
                 right trade when the memory term dominates (§Perf).
    """
    if cfg.mla_absorbed:
        return _mla_forward_absorbed(p, x, cfg, positions=positions, causal=causal)
    B, S, _ = x.shape
    H, nope, v_dim = cfg.n_heads, cfg.qk_nope, cfg.v_head_dim
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv, k_pe = _mla_kv_latent(p, x, cfg, positions)
    k_nope = (ckv @ p["wuk"]).reshape(B, S, H, nope)
    v = (ckv @ p["wuv"]).reshape(B, S, H, v_dim)
    q = constrain_attention_q(jnp.concatenate([q_nope, q_pe], axis=-1))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, cfg.qk_rope))], axis=-1)
    out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    out = out.reshape(B, S, H * v_dim) @ p["wo"]
    return constrain(out, "batch", None, "embed")


def _mla_forward_absorbed(p, x, cfg: ModelConfig, *, positions, causal=True):
    B, S, _ = x.shape
    H, nope, v_dim, kvl, rope = (cfg.n_heads, cfg.qk_nope, cfg.v_head_dim,
                                 cfg.kv_lora, cfg.qk_rope)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv, k_pe = _mla_kv_latent(p, x, cfg, positions)
    wuk = p["wuk"].reshape(kvl, H, nope)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, wuk)        # (B,S,H,kvl)
    # flash scales by (kvl+rope)^-1/2; the true scale is (nope+rope)^-1/2
    fix = ((kvl + rope) / (nope + rope)) ** 0.5
    q = jnp.concatenate([q_lat, q_pe], axis=-1) * jnp.asarray(fix, q_lat.dtype)
    q = constrain_attention_q(q)
    k = jnp.concatenate([ckv, k_pe], axis=-1)[:, :, None, :]  # (B,S,1,kvl+r)
    v = ckv[:, :, None, :]                                    # (B,S,1,kvl)
    o_lat = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    wuv = p["wuv"].reshape(kvl, H, v_dim)
    out = jnp.einsum("bqhk,khv->bqhv", o_lat, wuv)
    out = out.reshape(B, S, H * v_dim) @ p["wo"]
    return constrain(out, "batch", None, "embed")


def mla_prefill(p, x, cfg: ModelConfig, *, positions, cache):
    out = mla_forward(p, x, cfg, positions=positions)
    ckv, k_pe = _mla_kv_latent(p, x, cfg, positions)
    S = x.shape[1]
    cache = dict(
        cache,
        ckv=jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
        kpe=jax.lax.dynamic_update_slice(cache["kpe"], k_pe.astype(cache["kpe"].dtype), (0, 0, 0)),
        pos=jax.lax.dynamic_update_slice(cache["pos"], jnp.arange(S, dtype=jnp.int32), (0,)),
    )
    return out, cache


def mla_decode(p, x, cfg: ModelConfig, *, pos, cache):
    """Weight-absorbed latent decode: attention directly on the c_kv cache."""
    B = x.shape[0]
    H, nope, v_dim, kvl = cfg.n_heads, cfg.qk_nope, cfg.v_head_dim, cfg.kv_lora
    posb = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q_nope, q_pe = _mla_q(p, x, cfg, posb)            # (B,1,H,nope),(B,1,H,rope)
    ckv_t, kpe_t = _mla_kv_latent(p, x, cfg, posb)    # (B,1,kvl),(B,1,rope)
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_t.astype(cache["ckv"].dtype), (0, pos, 0))
    kpe = jax.lax.dynamic_update_slice(cache["kpe"], kpe_t.astype(cache["kpe"].dtype), (0, pos, 0))
    kpos = jax.lax.dynamic_update_slice(cache["pos"], pos[None].astype(jnp.int32), (pos,))

    wuk = p["wuk"].reshape(kvl, H, nope)
    # absorb W_uk into the query: (B,1,H,kvl)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32), wuk.astype(jnp.float32))
    scale = (nope + cfg.qk_rope) ** -0.5
    s = jnp.einsum("bqhk,bsk->bhqs", q_lat, ckv.astype(jnp.float32)) + jnp.einsum(
        "bqhr,bsr->bhqs", q_pe.astype(jnp.float32), kpe.astype(jnp.float32))
    s = s * scale
    valid = (kpos >= 0) & (kpos <= pos)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsk->bqhk", pattn, ckv.astype(jnp.float32))  # (B,1,H,kvl)
    wuv = p["wuv"].reshape(kvl, H, v_dim)
    out = jnp.einsum("bqhk,khv->bqhv", o_lat, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * v_dim).astype(x.dtype) @ p["wo"]
    return out, dict(cache, ckv=ckv, kpe=kpe, pos=kpos)


def make_mla_cache(cfg: ModelConfig, batch: int, seq_len: int, abstract=False):
    shapes = {
        "ckv": ((batch, seq_len, cfg.kv_lora), cfg.dtype),
        "kpe": ((batch, seq_len, cfg.qk_rope), cfg.dtype),
        "pos": ((seq_len,), jnp.int32),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(s, dt) for n, (s, dt) in shapes.items()}
    c = {n: jnp.zeros(s, dt) for n, (s, dt) in shapes.items()}
    c["pos"] = jnp.full((seq_len,), -1, jnp.int32)
    return c


def make_mla_lane_cache(cfg: ModelConfig, lanes: int, max_len: int, abstract=False):
    """Per-lane dense latent cache for the serve engine (the MLA latent is
    already ~20x smaller than expanded K/V, so lanes stay dense; only the
    position row is per-lane so lane reuse can invalidate stale slots)."""
    shapes = {
        "ckv": ((lanes, max_len, cfg.kv_lora), cfg.dtype),
        "kpe": ((lanes, max_len, cfg.qk_rope), cfg.dtype),
        "pos": ((lanes, max_len), jnp.int32),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(s, dt) for n, (s, dt) in shapes.items()}
    c = {n: jnp.zeros(s, dt) for n, (s, dt) in shapes.items()}
    c["pos"] = jnp.full((lanes, max_len), -1, jnp.int32)
    return c


def mla_decode_lanes(p, x, cfg: ModelConfig, *, pos, cache):
    """Weight-absorbed latent decode with per-lane positions (B,)."""
    B = x.shape[0]
    H, nope, v_dim, kvl = cfg.n_heads, cfg.qk_nope, cfg.v_head_dim, cfg.kv_lora
    posb = pos[:, None]
    q_nope, q_pe = _mla_q(p, x, cfg, posb)
    ckv_t, kpe_t = _mla_kv_latent(p, x, cfg, posb)
    bidx = jnp.arange(B)
    ckv = cache["ckv"].at[bidx, pos].set(ckv_t[:, 0].astype(cache["ckv"].dtype))
    kpe = cache["kpe"].at[bidx, pos].set(kpe_t[:, 0].astype(cache["kpe"].dtype))
    kpos = cache["pos"].at[bidx, pos].set(pos.astype(jnp.int32))

    wuk = p["wuk"].reshape(kvl, H, nope)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope.astype(jnp.float32), wuk.astype(jnp.float32))
    scale = (nope + cfg.qk_rope) ** -0.5
    s = jnp.einsum("bqhk,bsk->bhqs", q_lat, ckv.astype(jnp.float32)) + jnp.einsum(
        "bqhr,bsr->bhqs", q_pe.astype(jnp.float32), kpe.astype(jnp.float32))
    s = s * scale
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsk->bqhk", pattn, ckv.astype(jnp.float32))
    wuv = p["wuv"].reshape(kvl, H, v_dim)
    out = jnp.einsum("bqhk,khv->bqhv", o_lat, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * v_dim).astype(x.dtype) @ p["wo"]
    return out, dict(cache, ckv=ckv, kpe=kpe, pos=kpos)


def commit_prefill_mla(cache, dense, lane, *, stacked: bool):
    """Write a batch-1 dense MLA prefill cache into one lane's row, stamping
    -1 into position slots past the prompt so a reused lane never attends to
    the previous occupant's cache."""
    S = dense["ckv"].shape[-2]
    L = cache["pos"].shape[-1]
    row_pos = jnp.where(jnp.arange(L, dtype=jnp.int32) < S,
                        jnp.arange(L, dtype=jnp.int32), jnp.int32(-1))
    if stacked:
        ckv = cache["ckv"].at[:, lane, :S].set(dense["ckv"][:, 0].astype(cache["ckv"].dtype))
        kpe = cache["kpe"].at[:, lane, :S].set(dense["kpe"][:, 0].astype(cache["kpe"].dtype))
        kpos = cache["pos"].at[:, lane].set(row_pos[None])
    else:
        ckv = cache["ckv"].at[lane, :S].set(dense["ckv"][0].astype(cache["ckv"].dtype))
        kpe = cache["kpe"].at[lane, :S].set(dense["kpe"][0].astype(cache["kpe"].dtype))
        kpos = cache["pos"].at[lane].set(row_pos)
    return dict(cache, ckv=ckv, kpe=kpe, pos=kpos)


def mla_chunk_lanes(p, x, cfg: ModelConfig, *, positions, lengths, lanes,
                    cache):
    """Batched bucketed/chunked MLA prefill into per-lane latent rows.

    Mirrors ``mla_forward``'s math (absorbed or expanded, per config) over
    the lane's *stored* latent rows: the chunk's (c_kv, k_pe) are written at
    their absolute positions first (padded slots write back the old value),
    the position row is stamped ``j if j < start+length else -1`` (idempotent
    across chunks, invalidates a reused lane's stale slots), and the chunk
    queries attend over the full row under the stored-position mask.
    """
    B, Cb, _ = x.shape
    H, nope, v_dim, kvl, rope = (cfg.n_heads, cfg.qk_nope, cfg.v_head_dim,
                                 cfg.kv_lora, cfg.qk_rope)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv_t, kpe_t = _mla_kv_latent(p, x, cfg, positions)
    L = cache["ckv"].shape[1]
    valid = jnp.arange(Cb, dtype=jnp.int32)[None, :] < lengths[:, None]
    tgt = jnp.clip(positions, 0, L - 1)                           # (B,Cb)
    bl = lanes[:, None]
    old_ckv = cache["ckv"][bl, tgt]
    old_kpe = cache["kpe"][bl, tgt]
    ckv = cache["ckv"].at[bl, tgt].set(
        jnp.where(valid[..., None], ckv_t.astype(cache["ckv"].dtype), old_ckv))
    kpe = cache["kpe"].at[bl, tgt].set(
        jnp.where(valid[..., None], kpe_t.astype(cache["kpe"].dtype), old_kpe))
    ar = jnp.arange(L, dtype=jnp.int32)[None, :]
    limit = (positions[:, 0] + lengths)[:, None]                  # start+length
    row_pos = jnp.where(ar < limit, ar, jnp.int32(-1))            # (B,L)
    kpos = cache["pos"].at[lanes].set(row_pos)

    ckv_rows = ckv[lanes]                                         # (B,L,kvl)
    kpe_rows = kpe[lanes]                                         # (B,L,rope)
    if cfg.mla_absorbed:
        wuk = p["wuk"].reshape(kvl, H, nope)
        q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, wuk)
        fix = ((kvl + rope) / (nope + rope)) ** 0.5
        q = jnp.concatenate([q_lat, q_pe], axis=-1) * jnp.asarray(fix, q_lat.dtype)
        q = constrain_attention_q(q)
        kk = jnp.concatenate([ckv_rows, kpe_rows], axis=-1)[:, :, None, :]
        vv = ckv_rows[:, :, None, :]
        o_lat = _chunk_attention(q, kk, vv, q_pos=positions, k_pos=row_pos,
                                 chunk=cfg.attn_chunk)
        wuv = p["wuv"].reshape(kvl, H, v_dim)
        out = jnp.einsum("bqhk,khv->bqhv", o_lat, wuv)
    else:
        k_nope = (ckv_rows @ p["wuk"]).reshape(B, L, H, nope)
        vv = (ckv_rows @ p["wuv"]).reshape(B, L, H, v_dim)
        q = constrain_attention_q(jnp.concatenate([q_nope, q_pe], axis=-1))
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe_rows[:, :, None, :], (B, L, H, rope))],
            axis=-1)
        out = _chunk_attention(q, kk, vv, q_pos=positions, k_pos=row_pos,
                               chunk=cfg.attn_chunk)
    out = out.reshape(B, Cb, H * v_dim) @ p["wo"]
    return constrain(out, "batch", None, "embed"), dict(cache, ckv=ckv, kpe=kpe, pos=kpos)


# ---------------------------------------------------------------------------
# cross-attention (enc-dec)
# ---------------------------------------------------------------------------


def cross_forward(p, x, enc_out, cfg: ModelConfig):
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv, cfg.head_dim
    Se = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(B, S, H, D)
    k = (enc_out @ p["wk"]).reshape(B, Se, KV, D)
    v = (enc_out @ p["wv"]).reshape(B, Se, KV, D)
    out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return out.reshape(B, S, H * D) @ p["wo"]
