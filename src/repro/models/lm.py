"""Top-level language models: decoder-only CausalLM and enc-dec Seq2SeqLM.

Functional API:
  init(key) -> params                       (abstract_params() for dry-runs)
  forward(params, tokens, frames) -> logits (train / scoring path)
  loss(params, batch, rng) -> (loss, aux)   (next-token CE + MoE aux)
  init_cache(batch, seq_len [, enc_len])    (decode-entry cache pytree)
  prefill(params, batch, cache) -> (logits_last, cache)
  decode_step(params, token, cache, pos) -> (logits, cache)

Modality frontends ([audio]/[vlm]) are stubs per the assignment: ``frames``
are precomputed frame/patch embeddings supplied by input_specs(); the VLM
fuses them additively with token embeddings, the audio enc-dec feeds them
directly to the encoder.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .blocks import apply_stack, init_stack, init_stack_cache
from .common import constrain, embed_init, rms_norm, softmax_cross_entropy


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model), cfg.dtype),
            "stack": init_stack(ks[1], cfg, cross=cfg.is_encdec),
            "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype),
        }
        if not cfg.tied_embeddings:
            params["head"] = embed_init(ks[2], (cfg.d_model, cfg.vocab), cfg.dtype)
        if cfg.is_encdec:
            enc_cfg = _encoder_cfg(cfg)
            params["enc"] = {
                "stack": init_stack(ks[3], enc_cfg, cross=False),
                "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype),
            }
        return params

    def abstract_params(self) -> Dict:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------- embeddings
    def _embed(self, params, tokens, frames=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
        if frames is not None and not cfg.is_encdec:
            x = x + frames.astype(x.dtype)  # VLM stub: additive patch fusion
        return constrain(x, "batch", None, "embed")

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        head = params["embed"].T if cfg.tied_embeddings else params["head"]
        logits = x @ head
        return constrain(logits, "batch", None, "vocab")

    def _encode(self, params, frames):
        cfg = self.cfg
        enc_cfg = _encoder_cfg(cfg)
        x = frames.astype(cfg.dtype)
        pos = jnp.arange(x.shape[1])[None]
        x, _, _ = apply_stack(params["enc"]["stack"], x, enc_cfg, "fwd",
                              positions=pos, causal=False)
        return rms_norm(x, params["enc"]["ln_f"], cfg.norm_eps)

    # ---------------------------------------------------------------- forward
    def forward(self, params, tokens, frames=None):
        cfg = self.cfg
        enc_out = self._encode(params, frames) if cfg.is_encdec else None
        x = self._embed(params, tokens, frames)
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        x, aux, _ = apply_stack(params["stack"], x, cfg, "fwd",
                                positions=positions, enc_out=enc_out)
        return self._logits(params, x), aux

    def loss(self, params, batch, rng) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        logits, aux_moe = self.forward(params, batch["tokens"], batch.get("frames"))
        loss, aux = softmax_cross_entropy(logits, batch["labels"])
        if cfg.n_experts:
            loss = loss + cfg.aux_loss_coef * aux_moe
            aux["moe_aux"] = aux_moe
        return loss, aux

    # ----------------------------------------------------------------- caches
    def init_cache(self, batch: int, seq_len: int, enc_len: int = 0, abstract=False) -> Dict:
        cfg = self.cfg
        return init_stack_cache(cfg, batch, seq_len, enc_len=enc_len,
                                cross=cfg.is_encdec, abstract=abstract)

    def prefill(self, params, batch: Dict, cache: Dict):
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = self._encode(params, batch["frames"]) if cfg.is_encdec else None
        x = self._embed(params, tokens, batch.get("frames"))
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        x, _, cache = apply_stack(params["stack"], x, cfg, "prefill",
                                  positions=positions, caches=cache, enc_out=enc_out)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, token, cache: Dict, pos):
        """token: (B,1) int32; pos: scalar int32 (position being written)."""
        cfg = self.cfg
        x = self._embed(params, token)
        x, _, cache = apply_stack(params["stack"], x, cfg, "decode",
                                  caches=cache, pos=pos)
        return self._logits(params, x), cache

    def serve_step(self, params, token, cache: Dict, pos):
        """Greedy one-token serving step (what decode-shape cells lower)."""
        from repro.serving.sampling import sample_greedy

        logits, cache = self.decode_step(params, token, cache, pos)
        return sample_greedy(logits), cache

    # ------------------------------------------------------- paged serving
    def init_paged_cache(self, lanes: int, num_pages: int, page_size: int,
                         max_len: int, abstract=False) -> Dict:
        """Decode cache for the continuous-batching serve engine: shared KV
        page pools for attention layers (page 0 reserved as scratch),
        per-lane rows for MLA latents and recurrent state."""
        from .blocks import init_paged_stack_cache

        if self.cfg.is_encdec:
            raise NotImplementedError("paged serving supports decoder-only models")
        return init_paged_stack_cache(self.cfg, lanes, num_pages, page_size,
                                      max_len, abstract=abstract)

    def commit_prefill(self, paged: Dict, dense: Dict, table_row, lane, *,
                       prompt_len: int, page_size: int) -> Dict:
        """Gather-free handoff from a batch-1 dense prefill cache into the
        paged cache: prompt K/V scattered to the lane's pages (flat slot of
        logical j = table_row[j // page_size]*page_size + j % page_size),
        lane-dense leaves written at row ``lane``."""
        from .blocks import commit_stack_prefill

        idx = (table_row[:, None] * page_size +
               jnp.arange(page_size, dtype=jnp.int32)[None, :]).reshape(-1)[:prompt_len]
        return commit_stack_prefill(self.cfg, paged, dense, idx, lane)

    def prefill_commit_batch(self, params, tokens, paged: Dict, tables, lanes,
                             starts, lengths, fresh):
        """Batched bucketed/chunked prefill straight into the paged cache.

        ``tokens`` (B,Cb) right-padded chunk tokens, ``tables`` (B,T)
        page-table rows, ``lanes`` (B,) decode lanes, ``starts`` (B,)
        absolute position of each row's first token, ``lengths`` (B,) valid
        run, ``fresh`` (B,) bool first-chunk flag (zeroes prior recurrent
        state).  One signature per (Cb, B) bucket pair serves plain batched
        prefill (start=0), chunk continuation, and prefix-shared tails.
        Returns (logits (B,1,V) at each row's last valid token, new_paged).
        """
        cfg = self.cfg
        x = self._embed(params, tokens)
        Cb = tokens.shape[1]
        positions = starts[:, None] + jnp.arange(Cb, dtype=jnp.int32)[None, :]
        x, _, paged = apply_stack(params["stack"], x, cfg, "chunk",
                                  positions=positions, caches=paged,
                                  table=tables, lengths=lengths,
                                  lane_idx=lanes, fresh=fresh)
        last = jnp.take_along_axis(
            x, (lengths - 1).astype(jnp.int32)[:, None, None], axis=1)
        return self._logits(params, last), paged

    def decode_step_lanes(self, params, token, cache: Dict, table, pos,
                          live=None):
        """Per-lane decode: token (B,1); table (B,T) page tables; pos (B,)
        per-lane write positions (free lanes point at the scratch page);
        ``live`` (B,) bool holds idle lanes' per-lane dense cache rows (MLA
        latents, rec/ssm state — layers with no scratch row)."""
        cfg = self.cfg
        x = self._embed(params, token)
        x, _, cache = apply_stack(params["stack"], x, cfg, "decode",
                                  caches=cache, pos=pos, table=table, live=live)
        return self._logits(params, x), cache

    def serve_step_lanes(self, params, token, cache: Dict, table, pos,
                         live=None):
        from repro.serving.sampling import sample_greedy

        logits, cache = self.decode_step_lanes(params, token, cache, table,
                                               pos, live)
        return sample_greedy(logits), cache


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    """Encoder stack config: bidirectional full attention, n_enc_layers."""
    import dataclasses

    return dataclasses.replace(
        cfg,
        n_layers=cfg.n_enc_layers,
        pattern=("attn",),
        n_periods=cfg.n_enc_layers,
        tail=(),
        first_dense_layers=0,
        n_experts=0,
        n_enc_layers=0,
    )
