"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Default implementation is the GShard/Switch einsum dispatch — tokens are
grouped, assigned expert-buffer slots by intra-group cumsum, and moved with
one-hot dispatch/combine einsums. This partitions cleanly under GSPMD
(experts tensor-sharded on the model axis, groups on the data axes) at the
cost of dispatch FLOPs ~ 2*G*k*cf*group*d — visible in the roofline
MODEL_FLOPS/HLO ratio and attacked in the §Perf hillclimb via the
``ragged`` path (sort + jax.lax.ragged_dot, exact FLOPs).

Supports DeepSeek-style shared experts (always-on dense branch).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .common import activation, constrain, dense_init


def init_moe(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    ff = cfg.d_ff_expert or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wi": dense_init(ks[1], (E, d, ff), cfg.dtype),
        "wo": dense_init(ks[2], (E, ff, d), cfg.dtype, fan_in=ff),
    }
    if cfg.glu:
        p["wg"] = dense_init(ks[3], (E, d, ff), cfg.dtype)
    if cfg.n_shared:
        sff = ff * cfg.n_shared
        p["swi"] = dense_init(ks[4], (d, sff), cfg.dtype)
        p["swo"] = dense_init(ks[5], (sff, d), cfg.dtype, fan_in=sff)
        if cfg.glu:
            p["swg"] = dense_init(ks[6], (d, sff), cfg.dtype)
    return p


def _expert_ffn(p, xe, cfg: ModelConfig):
    """xe: (E, C, d) expert buffers -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    if cfg.glu:
        h = activation(h, cfg.activation) * jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    else:
        h = activation(h, cfg.activation)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def _shared_ffn(p, x, cfg: ModelConfig):
    h = x @ p["swi"]
    if cfg.glu:
        h = activation(h, cfg.activation) * (x @ p["swg"])
    else:
        h = activation(h, cfg.activation)
    return h @ p["swo"]


def moe_forward(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    n_tok = B * S
    # largest divisor of n_tok that fits the configured group size
    g = min(cfg.moe_group, n_tok)
    while n_tok % g:
        g -= 1
    ng = n_tok // g
    xt = x.reshape(ng, g, d)
    xt = constrain(xt, "batch", None, "embed")

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (ng,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                        # (ng,g,k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch): E * sum(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=(0, 1))
    onehot_top1 = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    if n_tok <= 256:
        # decode / tiny batches: exact per-token expert-weight gather
        # (capacity-free; the memory-bound form real MoE decode takes)
        y = _gather_moe(p, xt.reshape(n_tok, d), gate_vals.reshape(n_tok, k),
                        gate_idx.reshape(n_tok, k), cfg).reshape(ng, g, d)
        if cfg.n_shared:
            y = y + _shared_ffn(p, xt, cfg)
        return y.reshape(B, S, d), aux.astype(jnp.float32)

    capacity = int(max(1, round(cfg.capacity_factor * g * k / E)))

    if cfg.moe_impl == "einsum":
        # slot assignment: position of each (token, slot) within its expert
        disp_w = jnp.zeros((ng, g, E), jnp.float32)
        combine = jnp.zeros((ng, g, E, capacity), jnp.float32)
        prior = jnp.zeros((ng, 1, E), jnp.float32)
        for j in range(k):
            oh = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.float32)   # (ng,g,E)
            pos_in_e = jnp.cumsum(oh, axis=1) - 1.0 + prior               # (ng,g,E)
            keep = (pos_in_e < capacity).astype(jnp.float32) * oh
            prior = prior + jnp.sum(oh, axis=1, keepdims=True)
            pos_clip = jnp.clip(jnp.sum(pos_in_e * oh, -1), 0, capacity - 1)
            sel = jax.nn.one_hot(pos_clip.astype(jnp.int32), capacity, dtype=jnp.float32)
            combine = combine + gate_vals[..., j, None, None] * keep[..., None] * sel[..., None, :]
            disp_w = disp_w + keep
        dispatch = (combine > 0.0).astype(xt.dtype)                       # (ng,g,E,C)
        xe = jnp.einsum("ngec,ngd->necd", dispatch, xt)                   # (ng,E,C,d)
        xe = constrain(xe, "batch", None, None, "embed")
        ye = jax.vmap(lambda b: _expert_ffn(p, b, cfg))(xe)               # (ng,E,C,d)
        y = jnp.einsum("ngec,necd->ngd", combine.astype(xt.dtype), ye)
    elif cfg.moe_impl == "ragged":
        y = _ragged_moe(p, xt, gate_vals, gate_idx, cfg)
    else:
        raise ValueError(cfg.moe_impl)

    if cfg.n_shared:
        y = y + _shared_ffn(p, xt, cfg)
    return y.reshape(B, S, d), aux.astype(jnp.float32)


def _gather_moe(p, x, gate_vals, gate_idx, cfg: ModelConfig):
    """x: (n, d); per-token expert weight gather. Exact (no capacity)."""
    wi = jnp.take(p["wi"], gate_idx, axis=0)            # (n, k, d, ff)
    wo = jnp.take(p["wo"], gate_idx, axis=0)            # (n, k, ff, d)
    h = jnp.einsum("nd,nkdf->nkf", x, wi)
    if cfg.glu:
        wg = jnp.take(p["wg"], gate_idx, axis=0)
        h = activation(h, cfg.activation) * jnp.einsum("nd,nkdf->nkf", x, wg)
    else:
        h = activation(h, cfg.activation)
    y = jnp.einsum("nkf,nkfd->nkd", h, wo)
    return jnp.einsum("nkd,nk->nd", y, gate_vals.astype(y.dtype))


def _ragged_moe(p, xt, gate_vals, gate_idx, cfg: ModelConfig):
    """Sort-based grouped matmul path (exact FLOPs; §Perf hillclimb).

    Flattens groups, replicates each token k times, sorts by expert id and
    runs jax.lax.ragged_dot over per-expert contiguous rows.
    """
    ng, g, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    n = ng * g
    x_flat = xt.reshape(n, d)
    eid = gate_idx.reshape(n, k)
    gv = gate_vals.reshape(n, k)
    # replicate tokens k times
    tok_idx = jnp.repeat(jnp.arange(n), k)
    e_flat = eid.reshape(-1)
    w_flat = gv.reshape(-1).astype(xt.dtype)
    order = jnp.argsort(e_flat)
    tok_sorted = tok_idx[order]
    w_sorted = w_flat[order]
    xs = x_flat[tok_sorted]                                  # (n*k, d)
    group_sizes = jnp.bincount(e_flat, length=E).astype(jnp.int32)

    h = jax.lax.ragged_dot(xs, p["wi"], group_sizes)
    if cfg.glu:
        h = activation(h, cfg.activation) * jax.lax.ragged_dot(xs, p["wg"], group_sizes)
    else:
        h = activation(h, cfg.activation)
    ye = jax.lax.ragged_dot(h, p["wo"], group_sizes)         # (n*k, d)
    ye = ye * w_sorted[:, None]
    y = jnp.zeros((n, d), ye.dtype).at[tok_sorted].add(ye)
    return y.reshape(ng, g, d)


def init_mlp(key, cfg: ModelConfig) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d, ff), cfg.dtype),
        "wo": dense_init(ks[1], (ff, d), cfg.dtype, fan_in=ff),
    }
    if cfg.glu:
        p["wg"] = dense_init(ks[2], (d, ff), cfg.dtype)
    return p


def mlp_forward(p, x, cfg: ModelConfig):
    h = x @ p["wi"]
    if cfg.glu:
        h = activation(h, cfg.activation) * (x @ p["wg"])
    else:
        h = activation(h, cfg.activation)
    h = constrain(h, "batch", None, "mlp")
    return h @ p["wo"]
