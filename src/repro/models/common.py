"""Shared model building blocks: norms, RoPE/M-RoPE, inits, shard hints.

Models are *functional*: ``init(key, cfg) -> params`` (nested dicts of
arrays) and pure apply functions. Parameter names are stable and descriptive
(e.g. ``layers/attn/wq``) — the sharding layer maps name patterns to
PartitionSpecs (MaxText-style logical rules, see distributed/sharding.py),
and the analog trainer selects tiles by the same paths.
"""
from __future__ import annotations

import contextvars
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# sharding hints (active only when a launcher installs rules)
# ---------------------------------------------------------------------------

_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar("shard_rules", default=None)


def set_shard_rules(rules) -> None:
    """Install (mesh, {logical_name: mesh_axis|None}) for constrain()."""
    _ACTIVE_RULES.set(rules)


def constrain(x, *logical_axes: Optional[str]):
    """Apply with_sharding_constraint if launcher rules are active.

    Divisibility-aware: a hint whose dim doesn't divide by the mesh-axis
    size is dropped (padding a 8-head tensor onto a 16-way axis makes GSPMD
    thrash through involuntary rematerializations)."""
    rules = _ACTIVE_RULES.get()
    if rules is None:
        return x
    mesh, table = rules
    from jax.sharding import NamedSharding, PartitionSpec

    def axis_size(a):
        if a is None:
            return 1
        names = a if isinstance(a, tuple) else (a,)
        n = 1
        for nm in names:
            n *= mesh.shape[nm]
        return n

    axes = []
    for i, name in enumerate(logical_axes):
        a = table.get(name)
        n = axis_size(a)
        if a is not None and n > 1 and x.shape[i] % n == 0:
            axes.append(a)
        else:
            axes.append(None)
    if all(a is None for a in axes):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, PartitionSpec(*axes)))


def constrain_attention_q(q):
    """Shard a (B, Sq, H, D) query for attention: put the model axis on
    heads when H divides it, otherwise on the *sequence* dim (sequence-
    parallel attention) — without this, archs whose head count doesn't
    divide the model axis (e.g. 40 heads on 16 ways) leave the model axis
    idle and every device carries full S x chunk score blocks (§Perf)."""
    rules = _ACTIVE_RULES.get()
    if rules is None:
        return q
    mesh, table = rules
    from jax.sharding import NamedSharding, PartitionSpec

    model_ax = table.get("heads")
    batch_ax = table.get("batch")
    if model_ax is None:
        return q
    msize = mesh.shape[model_ax] if not isinstance(model_ax, tuple) else 0
    B, Sq, H, D = q.shape
    if msize and msize > 1 and H % msize == 0:
        spec = PartitionSpec(batch_ax, None, model_ax, None)
    else:
        # NOTE: a sequence-sharded fallback (Sq on the model axis) was tried
        # and refuted — without a fully sequence-parallel residual stream the
        # per-layer reshards cost more than the score sharding saves
        # (EXPERIMENTS.md §Perf, minicpm3 iterations 3-4).
        return q
    return jax.lax.with_sharding_constraint(q, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape: Sequence[int], dtype, fan_in: Optional[int] = None):
    fi = fan_in if fan_in is not None else shape[0]
    std = fi ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, base: float):
    half = head_dim // 2
    return base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, base: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S) int. Rotates pairs (even, odd
    halves split convention)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, base)  # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...], base: float = 10000.0):
    """Multimodal RoPE (Qwen2-VL): positions3 (3, ..., S) for (t, h, w);
    frequency channels are split into per-section groups, each rotated by its
    own position stream. ``sum(sections) == head_dim // 2``."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, d)
    freqs = rope_freqs(d, base)  # (half,)
    # build per-channel positions by section
    angs = []
    off = 0
    for i, sec in enumerate(sections):
        pos = positions3[i]  # (..., S)
        ang = pos[..., :, None].astype(jnp.float32) * freqs[off : off + sec]
        angs.append(ang)
        off += sec
    ang = jnp.concatenate(angs, axis=-1)  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None, z_loss: float = 1e-4):
    """Token-level CE in f32 with optional z-loss; returns (loss, aux)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - ll
    zl = z_loss * jnp.square(lse)
    per_tok = ce + zl
    if mask is None:
        mask = jnp.ones_like(ce)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(per_tok * mask) / denom
    acc = jnp.sum((jnp.argmax(lf, -1) == labels) * mask) / denom
    return loss, {"ce": jnp.sum(ce * mask) / denom, "accuracy": acc}
