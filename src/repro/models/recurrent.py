"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and Mamba-2 SSD.

Training paths use parallel forms (associative scan for RG-LRU; the chunked
matmul SSD algorithm for Mamba-2 — MXU-friendly). Decode paths carry
constant-size recurrent states, which is what makes the ``long_500k`` cell
tractable for these families.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .common import constrain, dense_init


# ---------------------------------------------------------------------------
# causal depthwise conv1d (shared)
# ---------------------------------------------------------------------------


def causal_conv(u, w, state=None, lengths=None):
    """u: (B,S,C); w: (k,C) depthwise causal. state: (B,k-1,C) prior inputs.

    Returns (y, new_state) where new_state holds the last k-1 inputs.
    With per-row ``lengths`` (B,) the new state gathers the last k-1 inputs
    *of the valid run* (right-padded batched prefill), reaching into the
    prior state for rows shorter than k-1; the padded tail never leaks into
    the carried state.
    """
    k = w.shape[0]
    if state is None:
        up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    S = u.shape[1]
    y = sum(w[j].astype(jnp.float32) * up[:, j : j + S].astype(jnp.float32) for j in range(k))
    if k <= 1:
        new_state = None
    elif lengths is None:
        new_state = up[:, -(k - 1):]
    else:
        # valid input t sits at up[:, t + k - 1]; want t = length-k+1..length-1
        idx = (lengths[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None, :])
        new_state = jnp.take_along_axis(up, idx[:, :, None], axis=1)
    return y.astype(u.dtype), new_state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin)
# ---------------------------------------------------------------------------


def _rglru_blocks(cfg: ModelConfig) -> int:
    """Gate matrices are block-diagonal by heads (Griffin) — TP-friendly:
    each model-parallel shard owns whole blocks, the diagonal recurrence and
    gates stay shard-local."""
    return max(1, cfg.n_heads)


def init_rglru(key, cfg: ModelConfig) -> Dict:
    d, dr = cfg.d_model, cfg.rnn_width
    nb = _rglru_blocks(cfg)
    bk = dr // nb
    ks = jax.random.split(key, 6)
    # Lambda init so a = sigma(lam)^(c*r) spreads over [0.9, 0.999]
    lam0 = jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, dr)) + 1e-8)
    return {
        "wx": dense_init(ks[0], (d, dr), cfg.dtype),
        "wy": dense_init(ks[1], (d, dr), cfg.dtype),
        "conv": dense_init(ks[2], (cfg.conv_k, dr), cfg.dtype, fan_in=cfg.conv_k),
        "war": dense_init(ks[3], (nb, bk, bk), cfg.dtype, fan_in=bk),
        "wai": dense_init(ks[4], (nb, bk, bk), cfg.dtype, fan_in=bk),
        "lam": lam0.astype(jnp.float32),
        "wout": dense_init(ks[5], (dr, d), cfg.dtype, fan_in=dr),
    }


def _block_gate(u, w):
    """u: (B,S,dr) x block-diag w: (nb,bk,bk) -> (B,S,dr)."""
    B, S, dr = u.shape
    nb, bk, _ = w.shape
    ub = u.reshape(B, S, nb, bk)
    out = jnp.einsum("bsnk,nkj->bsnj", ub, w)
    return out.reshape(B, S, dr)


def _rglru_gates(p, u, cfg: ModelConfig):
    r = jax.nn.sigmoid(_block_gate(u, p["war"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_gate(u, p["wai"]).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * r  # (B,S,dr) f32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, b


def _rglru_core(p, x, cfg: ModelConfig):
    gate = jax.nn.gelu((x @ p["wy"]).astype(jnp.float32), approximate=True)
    u, conv_state = causal_conv(x @ p["wx"], p["conv"])
    u = constrain(u, "batch", None, "mlp")
    a, b = _rglru_gates(p, u, cfg)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate * h).astype(x.dtype) @ p["wout"]
    return constrain(y, "batch", None, "embed"), h, conv_state


def rglru_forward(p, x, cfg: ModelConfig):
    """x: (B,S,d) -> (B,S,d). Parallel scan over time."""
    y, _, _ = _rglru_core(p, x, cfg)
    return y


def rglru_forward_with_state(p, x, cfg: ModelConfig):
    """Prefill: full forward + final recurrent/conv state."""
    y, h, conv_state = _rglru_core(p, x, cfg)
    return y, {"h": h[:, -1], "conv": conv_state}


def rglru_decode(p, x, state: Dict, cfg: ModelConfig):
    """x: (B,1,d); state: {'h': (B,dr) f32, 'conv': (B,k-1,dr)}."""
    gate = jax.nn.gelu((x @ p["wy"]).astype(jnp.float32), approximate=True)
    u, conv_state = causal_conv(x @ p["wx"], p["conv"], state["conv"])
    a, b = _rglru_gates(p, u, cfg)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (gate[:, 0] * h)[:, None].astype(x.dtype) @ p["wout"]
    return y, {"h": h, "conv": conv_state}


def make_rglru_state(cfg: ModelConfig, batch: int, abstract=False):
    dr = cfg.rnn_width
    shapes = {
        "h": ((batch, dr), jnp.float32),
        "conv": ((batch, cfg.conv_k - 1, dr), cfg.dtype),
    }
    if abstract:
        return {n: jax.ShapeDtypeStruct(s, dt) for n, (s, dt) in shapes.items()}
    return {n: jnp.zeros(s, dt) for n, (s, dt) in shapes.items()}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD — state space duality, chunked matmul form)
# ---------------------------------------------------------------------------


def init_ssm(key, cfg: ModelConfig) -> Dict:
    """Input projection split into per-stream matrices (z/x/B/C/dt) so each
    shards independently on the model axis (Mamba TP convention)."""
    d = cfg.d_model
    din = cfg.d_inner
    H, N, G = cfg.ssm_heads, cfg.d_state, cfg.ssm_groups
    ks = jax.random.split(key, 7)
    return {
        "wz": dense_init(ks[0], (d, din), cfg.dtype),
        "wx": dense_init(ks[1], (d, din), cfg.dtype),
        "wb": dense_init(ks[2], (d, G * N), cfg.dtype),
        "wc": dense_init(ks[3], (d, G * N), cfg.dtype),
        "wdt": dense_init(ks[4], (d, H), cfg.dtype),
        "conv_x": dense_init(ks[5], (cfg.d_conv, din), cfg.dtype, fan_in=cfg.d_conv),
        "conv_b": dense_init(jax.random.fold_in(ks[5], 1), (cfg.d_conv, G * N), cfg.dtype, fan_in=cfg.d_conv),
        "conv_c": dense_init(jax.random.fold_in(ks[5], 2), (cfg.d_conv, G * N), cfg.dtype, fan_in=cfg.d_conv),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((din,), cfg.dtype),
        "wout": dense_init(ks[6], (din, d), cfg.dtype, fan_in=din),
    }


def _segsum(x):
    """x: (..., L) -> (..., L, L) lower-tri cumulative segment sums."""
    L = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    d = xc[..., :, None] - xc[..., None, :]
    idx = jnp.arange(L)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt_a, B, C, chunk: int, init_state=None):
    """Chunked SSD (Mamba-2 alg. 3). x: (b,s,h,p) pre-multiplied by dt;
    dt_a: (b,s,h) = A*dt (<=0); B, C: (b,s,h,n). Returns (b,s,h,p).

    ``init_state`` (b,h,p,n) f32 seeds the inter-chunk recurrence (chunked
    serving prefill carries the state across calls); the scan combine is the
    same ``dec*prev + st`` the single-call recurrence applies, so splitting a
    sequence at ``chunk``-aligned boundaries reproduces the one-shot result."""
    b, s_orig, h, p_dim = x.shape
    n = B.shape[-1]
    L = min(chunk, s_orig)
    pad = (-s_orig) % L
    if pad:
        # zero x / dt_a padding is exact: decay over a padded tail is
        # exp(0)=1 and contributes no state, so earlier outputs and the
        # final state are unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_orig + pad
    c = s // L

    def ch(t):
        return t.reshape(b, c, L, *t.shape[2:])

    xc, dac, Bc, Cc = ch(x.astype(jnp.float32)), ch(dt_a.astype(jnp.float32)), ch(B.astype(jnp.float32)), ch(C.astype(jnp.float32))

    a_cum = jnp.cumsum(dac, axis=2)                                   # (b,c,L,h)
    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dac.swapaxes(2, 3)))                       # (b,c,h,L,L)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)                 # (b,c,h,L,S)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, Lmat,
                        xc, preferred_element_type=jnp.float32)

    # per-chunk final states
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)               # (b,c,L,h)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bc, decay_states, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[:, :, -1])                            # (b,c,h)

    def scan_fn(prev, inp):
        dec, st = inp
        new = dec[:, :, None, None] * prev + st
        return new, prev

    init = (jnp.zeros((b, h, p_dim, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn, init, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1))
    )
    prev_states = prev_states.swapaxes(0, 1)                          # (b,c,h,p,n)

    state_decay = jnp.exp(a_cum)                                      # (b,c,L,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p_dim)[:, :s_orig]
    return y, final_state


def _ssm_split(p, x, cfg: ModelConfig, conv_state=None, lengths=None):
    H, N, G = cfg.ssm_heads, cfg.d_state, cfg.ssm_groups
    z = x @ p["wz"]
    xs = x @ p["wx"]
    B_ = x @ p["wb"]
    C_ = x @ p["wc"]
    dt = x @ p["wdt"]                                                 # (B,S,H)
    cs = conv_state or {}
    xs, ncx = causal_conv(xs, p["conv_x"], cs.get("x"), lengths)
    B_, ncb = causal_conv(B_, p["conv_b"], cs.get("b"), lengths)
    C_, ncc = causal_conv(C_, p["conv_c"], cs.get("c"), lengths)
    new_conv = {"x": ncx, "b": ncb, "c": ncc}
    xs = jax.nn.silu(xs)
    B_ = jax.nn.silu(B_)
    C_ = jax.nn.silu(C_)
    Bsz, S = x.shape[0], x.shape[1]
    xs = xs.reshape(Bsz, S, H, cfg.ssm_head_dim)
    B_ = B_.reshape(Bsz, S, G, N)
    C_ = C_.reshape(Bsz, S, G, N)
    rep = H // G
    B_ = jnp.repeat(B_, rep, axis=2)
    C_ = jnp.repeat(C_, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if lengths is not None:
        # zero dt on padded rows makes the padding exact for the SSD scan:
        # x*dt contributes nothing and the decay exp(dt*A)=1 carries the
        # state through untouched (same identity ssd_chunked's internal
        # zero-padding relies on)
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < lengths[:, None]
        dt = jnp.where(valid[..., None], dt, 0.0)
    return z, xs, B_, C_, dt, new_conv


def _ssm_out(p, y, z, x, cfg: ModelConfig):
    from .common import rms_norm

    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    return y @ p["wout"]


def _ssm_core(p, x, cfg: ModelConfig, state=None, lengths=None):
    conv_state = state["conv"] if state is not None else None
    init_h = state["h"] if state is not None else None
    z, xs, B_, C_, dt, new_conv = _ssm_split(p, x, cfg, conv_state, lengths)
    A = -jnp.exp(p["a_log"])                                          # (H,)
    y, final = ssd_chunked(xs.astype(jnp.float32) * dt[..., None], dt * A, B_, C_, cfg.ssm_chunk,
                           init_state=init_h)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], cfg.d_inner)
    out = constrain(_ssm_out(p, y, z, x, cfg), "batch", None, "embed")
    return out, final, new_conv


def ssm_forward(p, x, cfg: ModelConfig):
    """x: (B,S,d) -> (B,S,d). Chunked SSD training path."""
    out, _, _ = _ssm_core(p, x, cfg)
    return out


def ssm_forward_with_state(p, x, cfg: ModelConfig, state=None, lengths=None):
    """Prefill: full forward + final (h, conv) state.

    ``state`` seeds a chunk-continuation prefill (the previous chunk's
    {'h','conv'}); ``lengths`` (B,) marks per-row valid runs in a
    right-padded batched prefill (dt masked to zero past them)."""
    out, final, new_conv = _ssm_core(p, x, cfg, state, lengths)
    return out, {"h": final, "conv": new_conv}


def ssm_decode(p, x, state: Dict, cfg: ModelConfig):
    """x: (B,1,d); state: {'h': (B,H,P,N) f32, 'conv': (B,k-1,conv_dim)}."""
    z, xs, B_, C_, dt, new_conv = _ssm_split(p, x, cfg, state["conv"])
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt[:, 0] * A)                                        # (B,H)
    xdt = xs[:, 0].astype(jnp.float32) * dt[:, 0][..., None]          # (B,H,P)
    h = dA[..., None, None] * state["h"] + jnp.einsum(
        "bhp,bhn->bhpn", xdt, B_[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, C_[:, 0].astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, cfg.d_inner)
    return _ssm_out(p, y, z[:, :1], x, cfg), {"h": h, "conv": new_conv}


def make_ssm_state(cfg: ModelConfig, batch: int, abstract=False):
    H, N, G = cfg.ssm_heads, cfg.d_state, cfg.ssm_groups
    km1 = cfg.d_conv - 1
    shapes = {
        "h": ((batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv": {
            "x": ((batch, km1, cfg.d_inner), cfg.dtype),
            "b": ((batch, km1, G * N), cfg.dtype),
            "c": ((batch, km1, G * N), cfg.dtype),
        },
    }

    def build(node):
        if isinstance(node, dict):
            return {k: build(v) for k, v in node.items()}
        s, dt = node
        return jax.ShapeDtypeStruct(s, dt) if abstract else jnp.zeros(s, dt)

    return build(shapes)
