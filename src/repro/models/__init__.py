"""Model zoo: composable layers + the 10 assigned architectures' backbones."""
from . import attention, blocks, common, convnets, lm, moe, recurrent  # noqa: F401
from .lm import LM  # noqa: F401
