"""The paper's own workloads: fully-analog FCN and LeNet-5 (App. F.3).

FCN:     784 -> 256 -> 128 -> 10, sigmoid hidden activations.
LeNet-5: conv5x5(16) -> pool -> conv5x5(32) -> pool -> fc512 -> fc128 -> 10,
         tanh hidden activations.

Both expose init/loss compatible with repro.core.trainer.AnalogTrainer; all
matmul/conv weights are analog-tileable (biases stay digital).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ConvNetConfig:
    kind: str = "fcn"          # fcn | lenet5
    n_classes: int = 10
    image_size: int = 28
    channels: int = 1


def init_convnet(key, cfg: ConvNetConfig) -> Dict:
    ks = jax.random.split(key, 8)

    def dense(k, shape):
        std = shape[0] ** -0.5
        return std * jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)

    if cfg.kind == "fcn":
        d_in = cfg.image_size * cfg.image_size * cfg.channels
        return {
            "fc1": {"w": dense(ks[0], (d_in, 256)), "b": jnp.zeros(256)},
            "fc2": {"w": dense(ks[1], (256, 128)), "b": jnp.zeros(128)},
            "out": {"w": dense(ks[2], (128, cfg.n_classes)), "b": jnp.zeros(cfg.n_classes)},
        }
    if cfg.kind == "lenet5":
        def conv(k, shape):  # HWIO
            fan_in = shape[0] * shape[1] * shape[2]
            return fan_in ** -0.5 * jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)

        s = cfg.image_size // 4  # two 2x2 pools
        return {
            "conv1": {"w": conv(ks[0], (5, 5, cfg.channels, 16)), "b": jnp.zeros(16)},
            "conv2": {"w": conv(ks[1], (5, 5, 16, 32)), "b": jnp.zeros(32)},
            "fc1": {"w": dense(ks[2], (s * s * 32, 512)), "b": jnp.zeros(512)},
            "fc2": {"w": dense(ks[3], (512, 128)), "b": jnp.zeros(128)},
            "out": {"w": dense(ks[4], (128, cfg.n_classes)), "b": jnp.zeros(cfg.n_classes)},
        }
    raise ValueError(cfg.kind)


def _conv2d(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def convnet_logits(params, images, cfg: ConvNetConfig):
    """images: (B, H, W, C) float32."""
    if cfg.kind == "fcn":
        x = images.reshape(images.shape[0], -1)
        x = jax.nn.sigmoid(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.sigmoid(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]
    x = jnp.tanh(_conv2d(images, params["conv1"]["w"], params["conv1"]["b"]))
    x = _maxpool(x)
    x = jnp.tanh(_conv2d(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = _maxpool(x)
    x = x.reshape(x.shape[0], -1)
    x = jnp.tanh(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jnp.tanh(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["out"]["w"] + params["out"]["b"]


def make_loss_fn(cfg: ConvNetConfig):
    def loss_fn(params, batch, rng) -> Tuple[jnp.ndarray, Dict]:
        logits = convnet_logits(params, batch["x"], cfg)
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return ce, {"accuracy": acc}

    return loss_fn


def analog_filter(path: str, leaf) -> bool:
    """All conv/fc weight matrices are analog (fully-analog nets, paper §4)."""
    return path.endswith("/w")
