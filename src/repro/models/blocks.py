"""Layer blocks + the period-scan stack machinery.

A model stack = ``prefix`` layers (unrolled; e.g. DeepSeek's leading dense-
FFN layer) + ``body`` = cfg.pattern repeated cfg.n_periods times (params
stacked on a scan axis per position-in-period — one period of HLO regardless
of depth) + ``tail`` layers (unrolled; e.g. RecurrentGemma's trailing
[rec, rec]).

Every layer kind owns: pre-norm -> sequence mixer -> residual -> pre-norm ->
MLP/MoE -> residual (SSD blocks have no separate MLP). Decoder stacks in
enc-dec models additionally carry a cross-attention sub-block.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec_mod
from .common import rms_norm


# ---------------------------------------------------------------------------
# single-layer init/apply
# ---------------------------------------------------------------------------


def _layer_is_moe(cfg: ModelConfig, global_idx: int) -> bool:
    return bool(cfg.n_experts) and global_idx >= cfg.first_dense_layers


def init_layer(key, cfg: ModelConfig, kind: str, global_idx: int, cross: bool = False) -> Dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), cfg.dtype)}
    if kind in ("attn", "attn_local"):
        p["attn"] = attn.init_attn(ks[0], cfg)
    elif kind == "mla":
        p["attn"] = attn.init_mla(ks[0], cfg)
    elif kind == "rec":
        p["mix"] = rec_mod.init_rglru(ks[0], cfg)
    elif kind == "ssm":
        p["mix"] = rec_mod.init_ssm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "ssm":
        p["ln2"] = jnp.zeros((d,), cfg.dtype)
        if _layer_is_moe(cfg, global_idx):
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        else:
            p["mlp"] = moe_mod.init_mlp(ks[1], cfg)
    if cross:
        p["lnx"] = jnp.zeros((d,), cfg.dtype)
        p["cross"] = attn.init_attn(ks[2], cfg, cross=True)
    return p


def make_layer_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     enc_len: int = 0, cross: bool = False, abstract=False) -> Dict:
    c: Dict[str, Any] = {}
    if kind in ("attn", "attn_local"):
        c["kv"] = attn.make_attn_cache(cfg, kind, batch, seq_len, abstract)
    elif kind == "mla":
        c["kv"] = attn.make_mla_cache(cfg, batch, seq_len, abstract)
    elif kind == "rec":
        c["state"] = rec_mod.make_rglru_state(cfg, batch, abstract)
    elif kind == "ssm":
        c["state"] = rec_mod.make_ssm_state(cfg, batch, abstract)
    if cross:
        KV, D = cfg.n_kv, cfg.head_dim
        shp = {"ck": ((batch, enc_len, KV, D), cfg.dtype),
               "cv": ((batch, enc_len, KV, D), cfg.dtype)}
        if abstract:
            c.update({n: jax.ShapeDtypeStruct(s, dt) for n, (s, dt) in shp.items()})
        else:
            c.update({n: jnp.zeros(s, dt) for n, (s, dt) in shp.items()})
    return c


def apply_layer(
    p: Dict,
    x,
    cfg: ModelConfig,
    kind: str,
    mode: str,                 # fwd | prefill | chunk | decode
    *,
    positions=None,
    cache: Optional[Dict] = None,
    pos=None,
    enc_out=None,
    causal: bool = True,
    table=None,                # (B,T) page table -> paged per-lane decode
    lengths=None,              # (B,) valid run per row   (mode="chunk")
    lane_idx=None,             # (B,) decode lane per row (mode="chunk")
    fresh=None,                # (B,) bool: first chunk — zero prior state
    live=None,                 # (B,) bool: lane is decoding (mode="decode")
) -> Tuple[Any, jnp.ndarray, Optional[Dict]]:
    """Returns (x_out, aux_loss, new_cache).

    ``live`` masks per-lane dense cache writes in paged decode: page-pool
    layers park idle lanes on the scratch page, but MLA latent rows and
    rec/ssm state have no scratch row — without the mask, the decode step
    running between prefill chunks would overwrite a mid-chunk lane's
    carried state with its placeholder-token garbage."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = dict(cache) if cache is not None else {}
    rs = cfg.residual_scale
    lanes = table is not None

    def hold_idle(new, old):
        if live is None:
            return new
        return jax.tree.map(
            lambda n, o: jnp.where(live.reshape((-1,) + (1,) * (n.ndim - 1)),
                                   n, o), new, old)

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        if mode == "fwd":
            mix = attn.attn_forward(p["attn"], h, cfg, kind=kind, positions=positions, causal=causal)
        elif mode == "prefill":
            mix, new_cache["kv"] = attn.attn_prefill(p["attn"], h, cfg, kind=kind,
                                                     positions=positions, cache=cache["kv"])
        elif mode == "chunk":
            mix, new_cache["kv"] = attn.attn_chunk_paged(p["attn"], h, cfg, kind=kind,
                                                         positions=positions, lengths=lengths,
                                                         table=table, cache=cache["kv"])
        elif lanes:
            mix, new_cache["kv"] = attn.attn_decode_paged(p["attn"], h, cfg, kind=kind,
                                                          pos=pos, table=table, cache=cache["kv"])
        else:
            mix, new_cache["kv"] = attn.attn_decode(p["attn"], h, cfg, kind=kind,
                                                    pos=pos, cache=cache["kv"])
    elif kind == "mla":
        if mode == "fwd":
            mix = attn.mla_forward(p["attn"], h, cfg, positions=positions, causal=causal)
        elif mode == "prefill":
            mix, new_cache["kv"] = attn.mla_prefill(p["attn"], h, cfg,
                                                    positions=positions, cache=cache["kv"])
        elif mode == "chunk":
            mix, new_cache["kv"] = attn.mla_chunk_lanes(p["attn"], h, cfg,
                                                        positions=positions, lengths=lengths,
                                                        lanes=lane_idx, cache=cache["kv"])
        elif lanes:
            mix, kv = attn.mla_decode_lanes(p["attn"], h, cfg,
                                            pos=pos, cache=cache["kv"])
            new_cache["kv"] = hold_idle(kv, cache["kv"])
        else:
            mix, new_cache["kv"] = attn.mla_decode(p["attn"], h, cfg, pos=pos, cache=cache["kv"])
    elif kind == "rec":
        if mode in ("fwd", "prefill"):
            if mode == "prefill":
                mix, new_cache["state"] = rec_mod.rglru_forward_with_state(p["mix"], h, cfg)
            else:
                mix = rec_mod.rglru_forward(p["mix"], h, cfg)
        elif mode == "chunk":
            # exact-length, fresh-only batched prefill: the engine never pads
            # or chunks rec rows (the associative scan's tree reassociation is
            # not bitwise-stable under a padded tail)
            mix, st = rec_mod.rglru_forward_with_state(p["mix"], h, cfg)
            new_cache["state"] = jax.tree.map(
                lambda lc, s: lc.at[lane_idx].set(s.astype(lc.dtype)),
                cache["state"], st)
        else:
            mix, st = rec_mod.rglru_decode(p["mix"], h, cache["state"], cfg)
            new_cache["state"] = hold_idle(st, cache["state"])
    elif kind == "ssm":
        if mode in ("fwd", "prefill"):
            if mode == "prefill":
                mix, new_cache["state"] = rec_mod.ssm_forward_with_state(p["mix"], h, cfg)
            else:
                mix = rec_mod.ssm_forward(p["mix"], h, cfg)
        elif mode == "chunk":
            def gather_row(lc):
                g = lc[lane_idx]
                mask = fresh.reshape((-1,) + (1,) * (g.ndim - 1))
                return jnp.where(mask, jnp.zeros((), g.dtype), g)

            prev = jax.tree.map(gather_row, cache["state"])
            mix, st = rec_mod.ssm_forward_with_state(p["mix"], h, cfg,
                                                     state=prev, lengths=lengths)
            new_cache["state"] = jax.tree.map(
                lambda lc, s: lc.at[lane_idx].set(s.astype(lc.dtype)),
                cache["state"], st)
        else:
            mix, st = rec_mod.ssm_decode(p["mix"], h, cache["state"], cfg)
            new_cache["state"] = hold_idle(st, cache["state"])
    else:
        raise ValueError(kind)
    x = x + rs * mix

    if "cross" in p:
        hx = rms_norm(x, p["lnx"], cfg.norm_eps)
        if mode == "fwd":
            cx = attn.cross_forward(p["cross"], hx, enc_out, cfg)
        else:
            # cross K/V cached (built at prefill); decode/prefill reuse them
            if mode == "prefill":
                B, Se, _ = enc_out.shape
                KV, D = cfg.n_kv, cfg.head_dim
                ck = (enc_out @ p["cross"]["wk"]).reshape(B, Se, KV, D)
                cv = (enc_out @ p["cross"]["wv"]).reshape(B, Se, KV, D)
                new_cache["ck"], new_cache["cv"] = ck, cv
                cx = attn.cross_forward(p["cross"], hx, enc_out, cfg)
            else:
                B = hx.shape[0]
                H, D = cfg.n_heads, cfg.head_dim
                q = (hx @ p["cross"]["wq"]).reshape(B, 1, H, D)
                Se = cache["ck"].shape[1]
                kpos = jnp.arange(Se, dtype=jnp.int32)
                out = attn.decode_attention(q, cache["ck"], cache["cv"],
                                            k_pos=kpos, pos=jnp.int32(Se))
                cx = out.reshape(B, 1, H * D) @ p["cross"]["wo"]
                new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
        x = x + rs * cx

    if kind != "ssm":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            ff, aux = moe_mod.moe_forward(p["moe"], h2, cfg)
        else:
            ff = moe_mod.mlp_forward(p["mlp"], h2, cfg)
        x = x + rs * ff
    return x, aux, (new_cache if (cache is not None or mode != "fwd") else None)


# ---------------------------------------------------------------------------
# stack machinery: prefix (unrolled) + body (scanned periods) + tail
# ---------------------------------------------------------------------------


def stack_structure(cfg: ModelConfig) -> Tuple[List[str], List[str], List[str], int]:
    kinds = list(cfg.layer_kinds)
    nprefix = cfg.first_dense_layers
    prefix = kinds[:nprefix]
    rest = kinds[nprefix:]
    period = list(cfg.pattern)
    tail = list(cfg.tail)
    # how many full periods fit in `rest` before the tail
    body_len = len(rest) - len(tail)
    assert body_len % len(period) == 0, (cfg.name, body_len, period)
    n_periods = body_len // len(period)
    return prefix, period, tail, n_periods


def init_stack(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    prefix, period, tail, n_periods = stack_structure(cfg)
    params: Dict[str, Any] = {"prefix": {}, "body": {}, "tail": {}}
    kidx = 0

    def nk():
        nonlocal kidx
        kidx += 1
        return jax.random.fold_in(key, kidx)

    for i, kind in enumerate(prefix):
        params["prefix"][f"l{i}"] = init_layer(nk(), cfg, kind, i, cross)
    for j, kind in enumerate(period):
        if n_periods == 0:
            continue
        keys = jax.random.split(nk(), n_periods)
        gidx = len(prefix) + j  # MoE-ness is uniform across periods by construction
        params["body"][f"p{j}"] = jax.vmap(
            lambda k: init_layer(k, cfg, kind, gidx, cross)
        )(keys)
    for i, kind in enumerate(tail):
        gidx = len(prefix) + n_periods * len(period) + i
        params["tail"][f"l{i}"] = init_layer(nk(), cfg, kind, gidx, cross)
    return params


def init_stack_cache(cfg: ModelConfig, batch: int, seq_len: int, *, enc_len=0,
                     cross=False, abstract=False) -> Dict:
    prefix, period, tail, n_periods = stack_structure(cfg)
    cache: Dict[str, Any] = {"prefix": {}, "body": {}, "tail": {}}
    for i, kind in enumerate(prefix):
        cache["prefix"][f"l{i}"] = make_layer_cache(cfg, kind, batch, seq_len, enc_len, cross, abstract)
    for j, kind in enumerate(period):
        if n_periods == 0:
            continue
        one = make_layer_cache(cfg, kind, batch, seq_len, enc_len, cross, abstract)

        def stack_leaf(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((n_periods,) + leaf.shape, leaf.dtype)
            return jnp.broadcast_to(leaf[None], (n_periods,) + leaf.shape).copy()

        cache["body"][f"p{j}"] = jax.tree.map(stack_leaf, one)
    for i, kind in enumerate(tail):
        cache["tail"][f"l{i}"] = make_layer_cache(cfg, kind, batch, seq_len, enc_len, cross, abstract)
    return cache


# ---------------------------------------------------------------------------
# paged decode caches (continuous-batching serve engine)
# ---------------------------------------------------------------------------
#
# Layout per layer kind:
#   attn/attn_local — shared page pools (num_pages, page_size, KV, D); all
#                     layers index the same per-lane page-table row.
#   mla             — per-lane dense latent rows (lanes, max_len, ...) with a
#                     per-lane position row for stale-slot invalidation.
#   rec/ssm         — per-lane recurrent state, identical to the dense cache.


def make_paged_layer_cache(cfg: ModelConfig, kind: str, lanes: int, num_pages: int,
                           page_size: int, max_len: int, abstract=False) -> Dict:
    c: Dict[str, Any] = {}
    if kind in ("attn", "attn_local"):
        c["kv"] = attn.make_paged_attn_cache(cfg, num_pages, page_size, abstract)
    elif kind == "mla":
        c["kv"] = attn.make_mla_lane_cache(cfg, lanes, max_len, abstract)
    elif kind == "rec":
        c["state"] = rec_mod.make_rglru_state(cfg, lanes, abstract)
    elif kind == "ssm":
        c["state"] = rec_mod.make_ssm_state(cfg, lanes, abstract)
    return c


def init_paged_stack_cache(cfg: ModelConfig, lanes: int, num_pages: int,
                           page_size: int, max_len: int, abstract=False) -> Dict:
    prefix, period, tail, n_periods = stack_structure(cfg)
    cache: Dict[str, Any] = {"prefix": {}, "body": {}, "tail": {}}

    def one(kind):
        return make_paged_layer_cache(cfg, kind, lanes, num_pages, page_size,
                                      max_len, abstract)

    for i, kind in enumerate(prefix):
        cache["prefix"][f"l{i}"] = one(kind)
    for j, kind in enumerate(period):
        if n_periods == 0:
            continue

        def stack_leaf(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((n_periods,) + leaf.shape, leaf.dtype)
            return jnp.broadcast_to(leaf[None], (n_periods,) + leaf.shape).copy()

        cache["body"][f"p{j}"] = jax.tree.map(stack_leaf, one(kind))
    for i, kind in enumerate(tail):
        cache["tail"][f"l{i}"] = one(kind)
    return cache


def commit_layer_prefill(cfg: ModelConfig, kind: str, paged: Dict, dense: Dict,
                         idx, lane, *, stacked: bool) -> Dict:
    """Write one layer's batch-1 dense prefill cache into the paged cache:
    K/V pages at flat slots ``idx`` (S,), lane-dense state at row ``lane``."""
    if kind in ("attn", "attn_local"):
        return dict(paged, kv=attn.commit_prefill_pages(paged["kv"], dense["kv"],
                                                        idx, stacked=stacked))
    if kind == "mla":
        return dict(paged, kv=attn.commit_prefill_mla(paged["kv"], dense["kv"],
                                                      lane, stacked=stacked))
    # rec / ssm: overwrite the lane's recurrent state
    if stacked:
        state = jax.tree.map(lambda lc, dc: lc.at[:, lane].set(dc[:, 0].astype(lc.dtype)),
                             paged["state"], dense["state"])
    else:
        state = jax.tree.map(lambda lc, dc: lc.at[lane].set(dc[0].astype(lc.dtype)),
                             paged["state"], dense["state"])
    return dict(paged, state=state)


def commit_stack_prefill(cfg: ModelConfig, paged: Dict, dense: Dict, idx, lane) -> Dict:
    """Walk the stack structure and commit every layer's prefill cache."""
    prefix, period, tail, n_periods = stack_structure(cfg)
    out: Dict[str, Any] = {"prefix": {}, "body": {}, "tail": {}}
    for i, kind in enumerate(prefix):
        out["prefix"][f"l{i}"] = commit_layer_prefill(
            cfg, kind, paged["prefix"][f"l{i}"], dense["prefix"][f"l{i}"],
            idx, lane, stacked=False)
    for j, kind in enumerate(period):
        if n_periods == 0:
            continue
        out["body"][f"p{j}"] = commit_layer_prefill(
            cfg, kind, paged["body"][f"p{j}"], dense["body"][f"p{j}"],
            idx, lane, stacked=True)
    for i, kind in enumerate(tail):
        out["tail"][f"l{i}"] = commit_layer_prefill(
            cfg, kind, paged["tail"][f"l{i}"], dense["tail"][f"l{i}"],
            idx, lane, stacked=False)
    return out


def apply_stack(
    params: Dict,
    x,
    cfg: ModelConfig,
    mode: str,
    *,
    positions=None,
    caches: Optional[Dict] = None,
    pos=None,
    enc_out=None,
    causal: bool = True,
    table=None,
    lengths=None,
    lane_idx=None,
    fresh=None,
    live=None,
) -> Tuple[Any, jnp.ndarray, Optional[Dict]]:
    prefix, period, tail, n_periods = stack_structure(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"prefix": {}, "body": {}, "tail": {}}

    def run_layer(p, x, kind, cache):
        return apply_layer(p, x, cfg, kind, mode, positions=positions,
                           cache=cache, pos=pos, enc_out=enc_out, causal=causal,
                           table=table, lengths=lengths, lane_idx=lane_idx,
                           fresh=fresh, live=live)

    # ---- prefix (unrolled)
    for i, kind in enumerate(prefix):
        c = caches["prefix"][f"l{i}"] if caches else None
        x, aux, nc = run_layer(params["prefix"][f"l{i}"], x, kind, c)
        aux_total += aux
        if nc is not None:
            new_caches["prefix"][f"l{i}"] = nc

    # ---- body (scan over periods)
    if n_periods > 0:
        body_params = tuple(params["body"][f"p{j}"] for j in range(len(period)))
        body_caches = (
            tuple(caches["body"][f"p{j}"] for j in range(len(period))) if caches else None
        )

        def period_fn(carry, xs):
            h, aux_acc = carry
            ps = xs[0]
            cs = xs[1] if body_caches is not None else (None,) * len(period)
            new_cs = []
            for j, kind in enumerate(period):
                h, aux, nc = run_layer(ps[j], h, kind, cs[j])
                aux_acc = aux_acc + aux
                new_cs.append(nc)
            ys = tuple(new_cs) if body_caches is not None else None
            return (h, aux_acc), ys

        fn = period_fn
        if cfg.remat and mode == "fwd":
            fn = jax.checkpoint(period_fn, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (body_params,) if body_caches is None else (body_params, body_caches)
        (x, aux_total), ys = jax.lax.scan(fn, (x, aux_total), xs)
        if body_caches is not None and ys is not None:
            for j in range(len(period)):
                new_caches["body"][f"p{j}"] = ys[j]

    # ---- tail (unrolled)
    for i, kind in enumerate(tail):
        c = caches["tail"][f"l{i}"] if caches else None
        x, aux, nc = run_layer(params["tail"][f"l{i}"], x, kind, c)
        aux_total += aux
        if nc is not None:
            new_caches["tail"][f"l{i}"] = nc

    return x, aux_total, (new_caches if caches is not None else None)
