"""Batched serving driver: continuous decode over a request queue.

Prefill-then-decode with a fixed decode batch; analog non-idealities apply
to the *deployed* weights (effective analog weights + optional IO-quantized
MVMs), which is the paper's deployment story: a model trained with E-RIDER
serves from the same analog arrays.

With ``--ckpt-dir`` the driver restores an analog TrainState written by
``repro.launch.train`` (``--algorithm`` must name the same plan the
checkpoint was trained under — single or mixed ``pattern=algorithm``
form) and serves the *effective* analog weights, per-group under each
stack's own TilePolicy.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 16 --prompt-len 32 --gen 32 \
      [--ckpt-dir /tmp/ckpt --algorithm erider]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data import BigramLM
from repro.models.lm import LM


def _restore_effective_params(model: LM, args):
    """Rebuild the training-time plan, restore the checkpoint through the
    (re-keying) elastic restore path, and merge effective analog weights.

    The restore template is built with ``abstract_state`` from
    ``eval_shape``'d params — no throwaway tile/optimizer state is ever
    materialized (at LM scale trainer.init would allocate several times
    the served weights just to be overwritten)."""
    from repro.checkpoint import ckpt
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig, merge_effective
    from repro.launch.train import make_plan

    plan = make_plan(args.algorithm, args.smoke)
    trainer = AnalogTrainer(
        model.loss,
        TrainerConfig(digital=DigitalOptConfig(kind="sgdm"),
                      schedule=ScheduleConfig(kind="constant", base_lr=0.0)),
        plan=plan)
    aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    template = trainer.abstract_state(aparams)
    state = ckpt.restore(template, args.ckpt_dir)
    print(f"[serve] restored step {int(np.asarray(state['step']))} from "
          f"{args.ckpt_dir} | {trainer.describe_plan(aparams)}", flush=True)
    return merge_effective(state["params"], state["tiles"], trainer.cfg.tile)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="",
                    help="serve effective analog weights from this "
                         "repro.launch.train checkpoint")
    ap.add_argument("--algorithm", default="erider",
                    help="plan of the checkpoint (see repro.launch.train)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg)
    if args.ckpt_dir:
        params = _restore_effective_params(model, args)
    else:
        params = model.init(jax.random.PRNGKey(0))
    data = BigramLM(vocab=cfg.vocab, seed=3)

    prefill = jax.jit(model.prefill, donate_argnums=(2,))
    step = jax.jit(model.serve_step, donate_argnums=(2,))

    max_len = args.prompt_len + args.gen
    total_tokens = 0
    t0 = time.time()
    n_batches = (args.requests + args.batch - 1) // args.batch
    for b in range(n_batches):
        batch = data.batch(b, args.batch, args.prompt_len)
        toks = jnp.asarray(batch["tokens"])
        feed = {"tokens": toks}
        if cfg.frontend:
            feed["frames"] = jnp.zeros(
                (args.batch, args.prompt_len, cfg.d_model), cfg.dtype)
        cache = model.init_cache(args.batch, max_len,
                                 enc_len=args.prompt_len if cfg.is_encdec else 0)
        logits, cache = prefill(params, feed, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for i in range(args.gen - 1):
            tok, cache = step(params, tok, cache, jnp.int32(args.prompt_len + i))
            out.append(np.asarray(tok))
        total_tokens += args.batch * args.gen
        seq = np.concatenate(out, axis=1)
        print(f"[serve] batch {b}: generated {seq.shape} first row: {seq[0, :12]}")
    dt = time.time() - t0
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s -> "
          f"{total_tokens / dt:.1f} tok/s (CPU smoke)")


if __name__ == "__main__":
    main()
