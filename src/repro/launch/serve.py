"""Serving driver CLI: continuous-batching decode over analog weights.

Two engines over the same workload:

  --engine continuous (default) — the ``repro.serving`` engine: paged KV
      cache (fixed-size pages, per-request alloc/free, scratch-page lanes),
      per-step admission of waiting prefills into freed decode lanes,
      prefill/decode disaggregation, per-request TTFT/TPOT latency
      percentiles, structured JSON logs and a shutdown run manifest.
  --engine fixed — the legacy fixed-decode-batch loop (kept as the
      benchmark baseline): batches of ``--batch`` requests prefill together
      and decode in lockstep for the longest generation in the batch.

Analog non-idealities apply to the *deployed* weights: with ``--ckpt-dir``
the driver restores an analog TrainState written by ``repro.launch.train``
(``--algorithm`` must name the same plan — single or mixed
``pattern=algorithm`` form) and serves the *effective* analog weights,
per-group under each stack's own TilePolicy.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 16 --prompt-len 32 --gen 32 --lanes 8 \
      [--ckpt-dir /tmp/ckpt --algorithm erider] \
      [--log-json serve_log.jsonl --manifest serve_manifest.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.serving import serve_defaults
from repro.data import BigramLM
from repro.models.lm import LM
from repro.serving import (EngineConfig, FeedBuilder, ServeEngine,
                           ServeRequest, Telemetry, load_effective_params,
                           sample_greedy)

# --age suffixes, in seconds (month = Julian year / 12)
AGE_UNITS = {"s": 1.0, "min": 60.0, "h": 3600.0, "d": 86400.0,
             "mo": 2629800.0, "yr": 31557600.0}


def parse_age(text: str) -> float:
    """'0', '90', '5min', '1h', '1d', '1mo', '1yr' -> seconds since the
    checkpoint was programmed (t0)."""
    import re

    m = re.fullmatch(r"\s*([0-9]*\.?[0-9]+)\s*([a-z]*)\s*", str(text))
    if not m or (m.group(2) and m.group(2) not in AGE_UNITS):
        raise ValueError(
            f"bad --age {text!r}: expected <number>[{'|'.join(AGE_UNITS)}]")
    return float(m.group(1)) * AGE_UNITS.get(m.group(2) or "s")


def build_workload(cfg, requests: int, prompt_len: int, gen: int, seed: int = 3,
                   gen_spread: int = 0, arrival_every: int = 0,
                   prefix_len: int = 0) -> List[ServeRequest]:
    """Deterministic request trace: both engines consume the same prompts.

    ``gen_spread`` alternates short/long generations around ``--gen``
    (mixed-length trace); ``arrival_every`` staggers arrivals one request
    every N engine steps (mixed-arrival trace — the fixed driver ignores
    arrivals, an oracle assumption in its favor); ``prefix_len`` gives every
    prompt a common leading run of that many tokens (shared-prefix trace —
    a system prompt — which ``--prefix-share`` turns into CoW page hits)."""
    data = BigramLM(vocab=cfg.vocab, seed=seed)
    prefix = None
    if prefix_len:
        if prefix_len >= prompt_len:
            raise ValueError(f"prefix_len={prefix_len} must be < prompt_len={prompt_len}")
        prefix = data.batch(10_000, 1, prefix_len)["tokens"][0].astype(np.int32)
    out = []
    for i in range(requests):
        tail_len = prompt_len - (prefix_len if prefix is not None else 0)
        prompt = data.batch(i, 1, tail_len)["tokens"][0].astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        n = gen if not gen_spread else max(1, gen + (gen_spread if i % 2 else -gen_spread))
        out.append(ServeRequest(request_id=f"req{i:04d}", prompt=prompt,
                                max_new_tokens=n,
                                arrival_step=i * arrival_every, seed=i))
    return out


def make_fixed_fns(model: LM):
    """Jitted (prefill, step) pair for ``run_fixed`` — build once and pass
    back in to reuse compile caches across calls (benchmark warmup)."""
    return (jax.jit(model.prefill, donate_argnums=(2,)),
            jax.jit(model.serve_step, donate_argnums=(2,)))


def run_fixed(model: LM, params, workload: List[ServeRequest], batch: int,
              telemetry: Optional[Telemetry] = None,
              fns=None) -> Dict[str, np.ndarray]:
    """The legacy fixed-decode-batch loop: FIFO groups of ``batch`` requests
    prefill together and decode in lockstep until the longest generation in
    the group completes (shorter requests ride along as dead lanes)."""
    cfg = model.cfg
    telemetry = telemetry or Telemetry()
    feed_builder = FeedBuilder(cfg)
    prefill, step = fns or make_fixed_fns(model)

    for req in workload:
        telemetry.request_submitted(req.request_id, req.prompt_len,
                                    req.max_new_tokens, req.arrival_step)
    results: Dict[str, np.ndarray] = {}
    for start in range(0, len(workload), batch):
        group = workload[start:start + batch]
        pad = batch - len(group)
        prompts = np.stack([r.prompt for r in group] + [group[0].prompt] * pad)
        S = prompts.shape[1]
        gen = max(r.max_new_tokens for r in group)
        cache = model.init_cache(batch, S + gen,
                                 enc_len=S if cfg.is_encdec else 0)
        logits, cache = prefill(params, feed_builder(prompts), cache)
        tok = sample_greedy(logits)
        out = [np.asarray(tok)]
        for r in group:
            telemetry.first_token(r.request_id)
        for i in range(gen - 1):
            tok, cache = step(params, tok, cache, jnp.int32(S + i))
            out.append(np.asarray(tok))
            for r in group:
                if i + 2 <= r.max_new_tokens:
                    telemetry.token(r.request_id)
        seq = np.concatenate(out, axis=1)
        for lane, r in enumerate(group):
            results[r.request_id] = seq[lane, :r.max_new_tokens].astype(np.int32)
            telemetry.request_finished(r.request_id, lane, start // batch)
    return results


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("continuous", "fixed"), default="continuous")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8,
                    help="decode batch of the fixed engine")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--gen-spread", type=int, default=0,
                    help="alternate gen +/- spread (mixed-length trace)")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="stagger arrivals every N engine steps")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="common prompt prefix length (shared-prefix trace)")
    ap.add_argument("--prefill-chunk", type=int, default=-1,
                    help="prefill chunk tokens (-1 = per-arch default, 0 = off)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="max prefill tokens per engine step (0 = unlimited; "
                         "chunked mode only — caps decode jitter)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="copy-on-write prompt-prefix KV page sharing")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter for temperature sampling (0 = off)")
    ap.add_argument("--lanes", type=int, default=0,
                    help="decode lanes (0 = per-arch serving default)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens (0 = per-arch default)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="KV pool pages per layer (0 = sized from workload)")
    ap.add_argument("--ckpt-dir", default="",
                    help="serve effective analog weights from this "
                         "repro.launch.train checkpoint")
    ap.add_argument("--algorithm", default="erider",
                    help="plan of the checkpoint (see repro.launch.train)")
    ap.add_argument("--age", default="0",
                    help="serve the checkpoint aged this long past t0 "
                         "(conductance drift + read noise): seconds or "
                         "<n>{s,min,h,d,mo,yr}, e.g. --age 1yr")
    ap.add_argument("--gdc", choices=("on", "off"), default="off",
                    help="Global Drift Compensation against the manifest's "
                         "t0 weight signatures")
    ap.add_argument("--log-json", default="", help="JSON log lines path")
    ap.add_argument("--manifest", default="", help="run manifest path")
    ap.add_argument("--dump-tokens", default="",
                    help="write {request_id: tokens} JSON (regression tests)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg)
    age_s = parse_age(args.age)
    gdc_on = args.gdc == "on"
    lifetime = None
    if args.ckpt_dir:
        params, report = load_effective_params(
            model, args.ckpt_dir, args.algorithm, args.smoke,
            age_s=age_s, gdc=gdc_on, with_report=True)
        if age_s > 0 or gdc_on:
            lifetime = report
            print(f"[serve] lifetime: age={age_s:.0f}s gdc={args.gdc} "
                  f"t0_signature={report['t0_signature']}")
    else:
        if age_s > 0 or gdc_on:
            raise SystemExit("--age/--gdc require --ckpt-dir (lifetime "
                             "applies to deployed analog weights)")
        params = model.init(jax.random.PRNGKey(0))

    workload = build_workload(cfg, args.requests, args.prompt_len, args.gen,
                              gen_spread=args.gen_spread,
                              arrival_every=args.arrival_every,
                              prefix_len=args.prefix_len)
    max_gen = max(r.max_new_tokens for r in workload)
    engine_mode = args.engine
    if engine_mode == "continuous" and cfg.is_encdec:
        print("[serve] enc-dec arch: falling back to the fixed-batch engine")
        engine_mode = "fixed"

    defaults = serve_defaults(cfg)
    t0 = time.monotonic()
    if engine_mode == "continuous":
        lanes = args.lanes or defaults.lanes
        page_size = args.page_size or defaults.page_size
        max_len = args.prompt_len + max_gen
        table_width = -(-max_len // page_size)
        num_pages = args.num_pages or (lanes * table_width + 1)
        chunk = (defaults.prefill_chunk if args.prefill_chunk < 0
                 else args.prefill_chunk)
        share = args.prefix_share or defaults.prefix_share
        ecfg = EngineConfig(lanes=lanes, page_size=page_size,
                            num_pages=num_pages, max_len=max_len,
                            log_path=args.log_json,
                            manifest_path=args.manifest,
                            prefill_chunk=chunk,
                            prefill_budget=args.prefill_budget,
                            prefix_share=share,
                            temperature=args.temperature, top_k=args.top_k)
        engine = ServeEngine(model, params, ecfg, arch=cfg.name,
                             checkpoint={"restored": bool(args.ckpt_dir),
                                         "dir": args.ckpt_dir,
                                         "algorithm": args.algorithm},
                             lifetime=lifetime)
        results, summary = engine.run(workload)
        lat = engine.telemetry.latency_summary()
        print(f"[serve] continuous: {summary['generated_tokens']} tokens in "
              f"{summary['wall_s']:.2f}s -> {summary['tokens_per_s']:.1f} tok/s | "
              f"ttft p50/p99 {lat['ttft']['p50'] * 1e3:.1f}/{lat['ttft']['p99'] * 1e3:.1f} ms | "
              f"tpot p50/p99 {lat['tpot']['p50'] * 1e3:.1f}/{lat['tpot']['p99'] * 1e3:.1f} ms")
    else:
        telemetry = Telemetry(log_path=args.log_json)
        results = run_fixed(model, params, workload, args.batch, telemetry)
        wall = time.monotonic() - t0
        summary = telemetry.run_summary(wall)
        if args.manifest:
            telemetry.write_manifest(
                args.manifest, arch=cfg.name,
                engine={"mode": "fixed", "lanes": args.batch,
                        "page_size": args.prompt_len + max_gen, "num_pages": 2,
                        "table_width": 1},
                checkpoint={"restored": bool(args.ckpt_dir),
                            "dir": args.ckpt_dir, "algorithm": args.algorithm},
                wall_s=wall, lifetime=lifetime)
        telemetry.close()
        print(f"[serve] fixed: {summary['generated_tokens']} tokens in "
              f"{summary['wall_s']:.2f}s -> {summary['tokens_per_s']:.1f} tok/s")

    if args.dump_tokens:
        with open(args.dump_tokens, "w") as f:
            json.dump({k: np.asarray(v).tolist() for k, v in results.items()},
                      f, sort_keys=True)
    first = workload[0].request_id
    print(f"[serve] {first} first tokens: {np.asarray(results[first])[:12]}")


if __name__ == "__main__":
    main()
