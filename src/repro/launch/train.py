"""Training driver CLI: analog LM training with checkpoint/restart, fault
tolerance and the full data pipeline.

On this CPU container it runs reduced configs end-to-end (see
examples/lm_analog_training.py); on a real fleet the same driver runs the
full configs — the mesh factory, sharding rules and train_step are exactly
the ones the multi-pod dry-run lowers.

``--algorithm`` takes either a single algorithm name (one policy on every
analog leaf) or a comma-separated mixed plan of ``pattern=algorithm`` rules
matched in order (globs, ``re:`` regexes, or bare substrings;
``digital`` is a valid algorithm):

  --algorithm erider
  --algorithm "attn=rider,**=erider"
  --algorithm "re:mlp/(wi|wg)$=ttv2,wo=rider,**=erider"

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 100 --algorithm erider --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import ARCHS, get_config
from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.tile import TileConfig
from repro.core.trainer import AnalogTrainer, TrainerConfig
from repro.checkpoint import ckpt
from repro.data import BigramLM, Prefetcher
from repro.distributed import sharding
from repro.distributed.fault import PreemptionHandler, StragglerMonitor
from repro.launch.mesh import make_host_mesh
from repro.models.common import set_shard_rules
from repro.models.lm import LM


def make_tile_cfg(algorithm: str, smoke: bool) -> TileConfig:
    # device_w carries PCM-grade lifetime coefficients (drift_nu ~ 0.06,
    # cf. the pcm_gst preset): checkpoints trained by this driver can be
    # aged and drift-compensated by repro.lifetime / bench_lifetime.
    dev = DeviceConfig(kind="softbounds", dw_min=2e-4 if smoke else 1e-4,
                       sigma_d2d=0.1, sigma_pm=0.3, sigma_c2c=0.05,
                       drift_nu=0.06, drift_nu_std=0.02, drift_t0=20.0,
                       prog_noise=0.01, prog_noise_slope=0.07, prog_rounds=3,
                       read_noise=0.005)
    dev_p = DeviceConfig(kind="softbounds", dw_min=2e-4 if smoke else 1e-4,
                         sigma_d2d=0.1, sigma_pm=0.3, sigma_c2c=0.05,
                         ref_mean=0.1, ref_std=0.1)
    return TileConfig(
        algorithm=algorithm, device_p=dev_p, device_w=dev,
        state_dtype=jnp.float32 if smoke else jnp.bfloat16,
        store_device=smoke, rng="threefry" if smoke else "hash",
        lr_p=0.5, lr_w=0.05, gamma=0.1, eta=0.5, chopper_p=0.05,
    )


def make_plan(algorithm: str, smoke: bool) -> api.AnalogPlan:
    """CLI ``--algorithm`` value -> AnalogPlan (see api.plan_from_spec)."""
    return api.plan_from_spec(algorithm, lambda a: make_tile_cfg(a, smoke))


def ckpt_extra(trainer, state) -> dict:
    """Extra manifest keys for ``ckpt.save``: the GDC t0 signatures of the
    effective analog weights (``repro.lifetime.gdc``). Serve-time Global
    Drift Compensation divides these programming-time references by the
    aged signatures to recover the per-matrix drift scale. Computed with
    the exact jitted function the serve side re-runs, over the exact
    merged tree it rebuilds, so an unaged restore reproduces every
    signature bit-for-bit (the GDC t0 token-identity contract)."""
    from repro.core.trainer import merge_effective
    from repro.lifetime import gdc

    tiles = state["tiles"]
    if not hasattr(tiles, "index"):
        return {}
    paths = [p for g, ps in tiles.index
             for p in ps
             if not (tiles.policy(g) is not None and tiles.policy(g).is_digital)]
    if not paths:
        return {}
    eff = merge_effective(state["params"], tiles, trainer.cfg.tile)
    sig_fn = jax.jit(lambda t: gdc.signature_tree(t, tuple(sorted(paths))))
    return {"gdc_signatures": {p: float(v)
                               for p, v in sig_fn(eff).items()}}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--algorithm", default="erider")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LM(cfg)
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    set_shard_rules(sharding.logical_rules(mesh))

    plan = make_plan(args.algorithm, args.smoke)
    tcfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgdm", clip_norm=1.0),
        schedule=ScheduleConfig(kind="cosine", base_lr=args.lr,
                                total_steps=args.steps, warmup_steps=min(20, args.steps // 5)),
    )
    trainer = AnalogTrainer(model.loss, tcfg, plan=plan,
                            mesh=mesh if mesh.size > 1 else None)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    print(f"[train] {trainer.describe_plan(params)}", flush=True)
    state = trainer.init(jax.random.PRNGKey(1), params)

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state = ckpt.restore(state, args.ckpt_dir)
        start_step = int(np.asarray(state["step"]))
        print(f"[train] restored checkpoint at step {start_step}")

    data = BigramLM(vocab=cfg.vocab, seed=7)
    prefetch = Prefetcher(
        lambda s: data.batch(s, args.batch, args.seq), start_step=start_step)

    step_fn = trainer.jit_step()
    preempt = PreemptionHandler()
    monitor = StragglerMonitor()
    history = []
    pending = None

    it = iter(prefetch)
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        monitor.start()
        state, metrics = step_fn(state, batch)
        straggler = monitor.stop()
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["straggler"] = bool(straggler)
            history.append(m)
            print(f"[train] step={step} loss={m['loss']:.4f} "
                  f"acc={m.get('accuracy', 0):.3f} "
                  f"sp_err={m.get('tile/sp_err', -1):.4f} ema_s={monitor.ema:.3f}",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            pending = ckpt.save(state, args.ckpt_dir, step + 1,
                                asynchronous=True,
                                extra=ckpt_extra(trainer, state))
        if preempt.should_stop:
            print("[train] preemption signal — checkpointing and exiting")
            if args.ckpt_dir:
                ckpt.save(state, args.ckpt_dir, step + 1,
                          extra=ckpt_extra(trainer, state))
            break
    prefetch.close()
    if args.ckpt_dir:
        if pending is not None:
            pending.join(timeout=60)
        ckpt.save(state, args.ckpt_dir, int(np.asarray(state["step"])),
                  extra=ckpt_extra(trainer, state))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=2)
    print(f"[train] done; stragglers flagged: {monitor.flagged}")


if __name__ == "__main__":
    main()
