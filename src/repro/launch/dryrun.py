import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 placeholder CPU devices back the production
meshes: (16,16)=(data,model) single-pod and (2,16,16)=(pod,data,model)
multi-pod. Everything is ShapeDtypeStruct-driven — no array is ever
allocated; ``compiled.memory_analysis()`` proves the cell fits HBM and
``cost_analysis()`` + the optimized HLO feed the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import api  # noqa: E402
from repro.configs import SHAPES, ARCHS, get_config, input_specs, shape_applicable  # noqa: E402
from repro.core.device import DeviceConfig  # noqa: E402
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig  # noqa: E402
from repro.core.tile import TileConfig  # noqa: E402
from repro.core.trainer import AnalogTrainer, TrainerConfig  # noqa: E402
from repro.distributed import sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.common import set_shard_rules  # noqa: E402
from repro.models.lm import LM  # noqa: E402
from repro.roofline import analysis  # noqa: E402

# LM-scale analog tile config: bf16 state, regenerated device params
# (store_device=False), E-RIDER by default (the paper's headline method).
LM_DEVICE = DeviceConfig(kind="softbounds", dw_min=1e-4, sigma_d2d=0.1,
                         sigma_pm=0.3, sigma_c2c=0.05)
LM_DEVICE_P = DeviceConfig(kind="softbounds", dw_min=1e-4, sigma_d2d=0.1,
                           sigma_pm=0.3, sigma_c2c=0.05,
                           ref_mean=0.1, ref_std=0.1)

# per-arch microbatch count for train_4k (global batch 256)
MICROBATCH = {
    "deepseek-v2-236b": 16,
    "mixtral-8x7b": 16,
    "recurrentgemma-9b": 16,
    "qwen3-14b": 16,
    "gemma3-4b": 8,
    "minicpm3-4b": 8,
    "mamba2-2.7b": 8,
    "qwen2-0.5b": 4,
    "qwen2-vl-2b": 4,
    "seamless-m4t-large-v2": 4,
}


def make_tile_cfg(algorithm: str = "erider") -> TileConfig:
    return TileConfig(
        algorithm=algorithm,
        device_p=LM_DEVICE_P,
        device_w=LM_DEVICE,
        state_dtype=jnp.bfloat16,
        store_device=False,
        rng="hash",
        lr_p=0.5, lr_w=0.05, gamma=0.1, eta=0.5, chopper_p=0.05,
    )


def make_plan(algorithm: str = "erider") -> api.AnalogPlan:
    """LM-scale AnalogPlan. ``algorithm`` is a single name or a
    comma-separated ``pattern=algorithm`` mixed plan (globs / ``re:``
    regexes / bare substrings), e.g. "attn=rider,**=erider" — parsed by
    ``api.plan_from_spec`` with the dry-run's LM-scale TileConfigs."""
    return api.plan_from_spec(algorithm, make_tile_cfg)


def make_trainer(model: LM, arch: str, algorithm: str, dsize: int,
                 tile_engine: str = "grouped", mesh=None) -> AnalogTrainer:
    mb = MICROBATCH.get(arch, 2)
    mb = max(1, min(mb, 256 // dsize))
    tcfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgdm", clip_norm=0.0),
        schedule=ScheduleConfig(kind="cosine", base_lr=0.1, total_steps=10000),
        microbatch=mb,
        accum_dtype=jnp.bfloat16,
        engine=tile_engine,
    )
    return AnalogTrainer(model.loss, tcfg, plan=make_plan(algorithm),
                         mesh=mesh)


# perf-iteration options (see EXPERIMENTS.md §Perf):
#   zero_tiles: bool — ZeRO-shard tile state over the data axes (per-
#       microbatch weight all-gathers; disable when state fits model-sharded)
#   moe_impl: einsum | ragged — dispatch implementation
#   remat: bool — activation checkpointing of the layer-period scan
#   attn_chunk / microbatch / moe_group: overrides
DEFAULT_OPTS = dict(zero_tiles=True, moe_impl=None, remat=None,
                    attn_chunk=None, microbatch=None, moe_group=None,
                    mla_absorbed=None, tile_engine="grouped")


def build_cell(arch: str, shape_name: str, mesh, *, algorithm: str = "erider",
               opts=None):
    """Returns (lower_fn, model_flops, plan_line) for one cell;
    lower_fn() -> Lowered. plan_line is the trainer's one-line AnalogPlan
    summary (None for prefill/decode cells)."""
    import dataclasses as _dc

    o = dict(DEFAULT_OPTS, **(opts or {}))
    cfg = get_config(arch)
    over = {}
    if o["moe_impl"] is not None:
        over["moe_impl"] = o["moe_impl"]
    if o["remat"] is not None:
        over["remat"] = o["remat"]
    if o["attn_chunk"] is not None:
        over["attn_chunk"] = o["attn_chunk"]
    if o["moe_group"] is not None:
        over["moe_group"] = o["moe_group"]
    if o["mla_absorbed"] is not None:
        over["mla_absorbed"] = o["mla_absorbed"]
    if over:
        cfg = _dc.replace(cfg, **over)
    spec = SHAPES[shape_name]
    model = LM(cfg)
    aparams = model.abstract_params()
    _, dsize, _, _ = sharding.mesh_axis_sizes(mesh)
    batch_specs = input_specs(cfg, shape_name)
    mflops = analysis.model_flops_for(cfg, spec)

    if spec.kind == "train":
        trainer = make_trainer(model, arch, algorithm, dsize,
                               tile_engine=o["tile_engine"], mesh=mesh)
        if o["microbatch"] is not None:
            trainer.cfg = _dc.replace(trainer.cfg, microbatch=o["microbatch"])
        astate = trainer.abstract_state(aparams)
        in_sh = (sharding.state_shardings(astate, mesh,
                                          zero_states=o["zero_tiles"]),
                 sharding.batch_shardings(batch_specs, mesh))

        def lower():
            fn = jax.jit(trainer.train_step, in_shardings=in_sh,
                         donate_argnums=(0,))
            return fn.lower(astate, batch_specs)

        return lower, mflops, trainer.describe_plan(aparams)

    p_sh = sharding.params_shardings(aparams, mesh)

    if spec.kind == "prefill":
        enc_len = spec.seq_len if cfg.is_encdec else 0
        acache = model.init_cache(spec.global_batch, spec.seq_len,
                                  enc_len=enc_len, abstract=True)
        c_sh = sharding.cache_shardings(acache, mesh)
        in_sh = (p_sh, sharding.batch_shardings(batch_specs, mesh), c_sh)

        def lower():
            fn = jax.jit(model.prefill, in_shardings=in_sh, donate_argnums=(2,))
            return fn.lower(aparams, batch_specs, acache)

        return lower, mflops, None

    # decode: serve_step(params, token, cache, pos)
    enc_len = min(spec.seq_len, 32768) if cfg.is_encdec else 0
    acache = model.init_cache(spec.global_batch, spec.seq_len,
                              enc_len=enc_len, abstract=True)
    c_sh = sharding.cache_shardings(acache, mesh)
    tok = batch_specs["tokens"]
    pos = batch_specs["pos"]
    in_sh = (p_sh, sharding.batch_shardings({"t": tok}, mesh)["t"], c_sh, None)

    def lower():
        fn = jax.jit(model.serve_step, in_shardings=in_sh, donate_argnums=(2,))
        return fn.lower(aparams, tok, acache, pos)

    return lower, mflops, None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             algorithm: str = "erider", tag: str = "", opts=None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")

    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
              "opts": {k: v for k, v in (opts or {}).items() if v is not None}}
    if not ok:
        result.update(status="skipped", reason=reason)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[dryrun] {cell_id}: SKIPPED ({reason})", flush=True)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    set_shard_rules(sharding.logical_rules(mesh))
    chips = mesh.size
    try:
        t0 = time.time()
        lower_fn, mflops, plan_line = build_cell(arch, shape_name, mesh,
                                                 algorithm=algorithm,
                                                 opts=opts)
        if plan_line:
            result["plan"] = plan_line
            print(f"[dryrun] {cell_id}: {plan_line}", flush=True)
        with mesh:
            lowered = lower_fn()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        roof = analysis.analyze(
            arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
            cost=cost, hlo_text=hlo, model_flops=mflops, memstats=mem)
        result.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                peak_per_device_gb=round(
                    (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
            ),
            roofline=roof.to_json(),
        )
        print(f"[dryrun] {cell_id}: OK compile={t_compile:.0f}s "
              f"mem/dev={result['memory']['peak_per_device_gb']}GB "
              f"bottleneck={roof.bottleneck} frac={roof.roofline_fraction:.3f}",
              flush=True)
    except Exception as e:  # noqa: BLE001 — report and continue the sweep
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {cell_id}: ERROR {type(e).__name__}: {str(e)[:200]}",
              flush=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="full 40-cell sweep")
    ap.add_argument("--algorithm", default="erider")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration runs")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-zero-tiles", action="store_true")
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "ragged"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--tile-engine", default="grouped",
                    choices=["grouped", "looped"],
                    help="looped = legacy per-tile update loop (baseline)")
    args = ap.parse_args(argv)
    opts = dict(zero_tiles=not args.no_zero_tiles, moe_impl=args.moe_impl,
                remat=False if args.no_remat else None,
                attn_chunk=args.attn_chunk, microbatch=args.microbatch,
                moe_group=args.moe_group,
                mla_absorbed=True if args.mla_absorbed else None,
                tile_engine=args.tile_engine)

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else sorted(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
                path = os.path.join(args.out, cell + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] {cell}: cached", flush=True)
                            continue
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         algorithm=args.algorithm, tag=args.tag, opts=opts)


if __name__ == "__main__":
    main()
