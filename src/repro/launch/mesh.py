"""Production mesh factory.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run pins the
device count via XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType

    def _mesh(shape, axes):
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # jax 0.4.x: all axes are implicitly Auto

    def _mesh(shape, axes):
        return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single-pod, or 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any (pods, data, model) factorization of the fleet."""
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pods: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke).

    ``pods > 1`` adds the leading "pod" axis so host-device tests exercise
    the multi-pod ZeRO path (tile stacks shard over pod x data) with the
    same axis names the production mesh uses.
    """
    n = len(jax.devices())
    assert pods * data * model <= n, (pods, data, model, n)
    if pods > 1:
        return _mesh((pods, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))
