"""Deterministic synthetic datasets (the container is offline — DESIGN.md §7).

* ``bigram_lm``: token streams from a fixed random bigram transition table —
  has learnable structure (a model reduces CE below the unigram entropy), is
  reproducible across hosts from (seed, step), and needs no storage.
* ``procedural_images``: MNIST/CIFAR-stand-in — per-class smooth prototypes
  + structured noise + random shifts. Same shapes/splits as the originals so
  the paper-repro benchmarks (LeNet-5 / FCN / ResNet-ish) run unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# bigram LM stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BigramLM:
    vocab: int
    seed: int = 0
    concentration: float = 0.3  # lower -> peakier transitions (more learnable)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        logits = rng.gumbel(size=(self.vocab, self.vocab)) / self.concentration
        # keep the table compact: top-8 successors per token
        top = np.argsort(-logits, axis=1)[:, :8]
        self._succ = top.astype(np.int32)

    def batch(self, step: int, batch: int, seq_len: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        choices = rng.integers(0, 8, size=(batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# procedural image classification (MNIST / CIFAR stand-ins)
# ---------------------------------------------------------------------------


def procedural_images(
    n: int,
    *,
    n_classes: int = 10,
    size: int = 28,
    channels: int = 1,
    seed: int = 0,
    noise: float = 0.2,
    sample_seed: int = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (n,size,size,channels) f32 in [0,1], y (n,) i32).

    ``seed`` fixes the class prototypes; ``sample_seed`` (default: seed)
    drives the per-sample noise/shift draws — train/test splits share
    prototypes but use different sample seeds.
    """
    proto_rng = np.random.default_rng(seed)
    rng = proto_rng  # prototypes consume from the prototype stream
    # smooth class prototypes: superposition of a few 2-D gaussian blobs
    protos = np.zeros((n_classes, size, size, channels), np.float32)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    for c in range(n_classes):
        for _ in range(5):
            cx, cy = rng.uniform(0.2, 0.8, 2)
            sx, sy = rng.uniform(0.08, 0.25, 2)
            amp = rng.uniform(0.6, 1.0)
            blob = amp * np.exp(-(((xx - cx) / sx) ** 2 + ((yy - cy) / sy) ** 2))
            ch = rng.integers(0, channels)
            protos[c, :, :, ch] += blob
    protos /= protos.max(axis=(1, 2, 3), keepdims=True) + 1e-6

    rng = np.random.default_rng(seed if sample_seed is None else sample_seed)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = protos[y].copy()
    # random +-1px shifts
    sh = rng.integers(-1, 2, size=(n, 2))
    for i in range(n):
        x[i] = np.roll(x[i], sh[i], axis=(0, 1))
    x += noise * rng.standard_normal(x.shape).astype(np.float32)
    return np.clip(x, 0.0, 1.0), y


@dataclasses.dataclass
class ImageDataset:
    """Epoch-shuffled minibatch iterator over a procedural image set."""

    n_train: int = 8192
    n_test: int = 2048
    n_classes: int = 10
    size: int = 28
    channels: int = 1
    seed: int = 0

    def __post_init__(self):
        self.x_train, self.y_train = procedural_images(
            self.n_train, n_classes=self.n_classes, size=self.size,
            channels=self.channels, seed=self.seed, sample_seed=self.seed + 1000)
        self.x_test, self.y_test = procedural_images(
            self.n_test, n_classes=self.n_classes, size=self.size,
            channels=self.channels, seed=self.seed, sample_seed=self.seed + 2000)

    def epoch(self, epoch_idx: int, batch: int) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng((self.seed, epoch_idx))
        order = rng.permutation(self.n_train)
        for i in range(0, self.n_train - batch + 1, batch):
            sel = order[i : i + batch]
            yield {"x": self.x_train[sel], "y": self.y_train[sel]}

    def test_batches(self, batch: int) -> Iterator[Dict[str, np.ndarray]]:
        for i in range(0, self.n_test - batch + 1, batch):
            yield {"x": self.x_test[i : i + batch], "y": self.y_test[i : i + batch]}
