"""Host data pipeline: background prefetch + device placement with shardings.

Single-process here, but the layout matches a multi-host deployment: each
host materializes only its addressable shard of the global batch (the
``BigramLM`` stream is deterministic in (seed, step), so host h slices rows
[h*B/H, (h+1)*B/H) of the same global batch — no data service needed).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    """Wraps a batch-producing callable with a depth-N background queue."""

    def __init__(self, producer: Callable[[int], Dict[str, np.ndarray]],
                 start_step: int = 0, depth: int = 2,
                 shardings: Optional[Dict] = None):
        self.producer = producer
        self.shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self.shardings is None:
            return batch
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, self.shardings
        )

    def _run(self):
        while not self._stop.is_set():
            batch = self.producer(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._place(self._q.get())

    def close(self):
        self._stop.set()
