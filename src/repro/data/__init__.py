from .pipeline import Prefetcher  # noqa: F401
from .synthetic import BigramLM, ImageDataset, procedural_images  # noqa: F401
