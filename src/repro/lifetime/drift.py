"""Conductance drift + programming-error transforms over effective weights.

Physics (Rasch et al. HWA replications — SNIPPETS.md snippets 1 and 3,
generalized to any ``DeviceConfig`` preset per arXiv 2502.06309):

  programming   one write lands at ``w + N(0, sigma_p(w)^2)`` with the
                state-dependent ``sigma_p(w) = prog_noise +
                prog_noise_slope * |w|``; write-and-verify re-reads the
                cell (read-noise corrupted) and issues a corrective write
                whose own error is proportional to the correction, so the
                residual shrinks geometrically with ``prog_rounds``.

  drift         ``W(t) = W(t0) * (t/t0)^-nu`` with a frozen per-element
                exponent ``nu ~ N(drift_nu, drift_nu_std^2)`` clipped at 0
                (sampled once per device, not per read).

  read noise    additive ``N(0, read_noise^2)`` on any post-t0 read.

All randomness comes from ``kernels.fastrng`` hash draws keyed by (seed,
salt): bit-reproducible across devices/shardings, fused into the consumer
(no materialized noise arrays), and — critically for the serve-time
contract — *frozen per deployment*, so reading twice at the same ``t``
returns the same array.

Units: the additive noise coefficients are fractions of the device's
conductance range. ``program_weights`` acts on tile-space weights (already
conductance-range units — it clips at tau), so its coefficients apply
directly. ``apply_lifetime`` acts on *model-space* effective weights (tile
weight x digital scales), so its additive ``read_noise`` is converted per
tensor by the amplitude ``amax(|w|)`` — the model-space value a full-range
conductance represents. Drift itself is multiplicative and scale-free.

``t == cfg.drift_t0`` is a bit-exact no-op by construction: the checkpoint
records the *verified post-program state at t0* (programming error is
what `program_weights` models for freshly written arrays, not something
retroactively applied to trained state), and the drift/read-noise branch
is bypassed entirely via ``jnp.where`` on the exact time match.
"""
from __future__ import annotations

import zlib
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import DeviceConfig
from repro.core.paths import path_str
from repro.kernels import fastrng

# fastrng salt namespace: core/device.py owns 11/13/17, sampling owns its
# own keyspace; lifetime draws live at 23+ so a (seed, salt) pair never
# collides with d2d sampling on the same key.
SALT_NU = 23          # per-element drift exponent (frozen per deployment)
SALT_READ = 29        # read noise at age t (frozen per deployment)
SALT_PROG = 31        # programming write error, round r -> SALT_PROG + 2r
SALT_VERIFY = 37      # verify-read error, round r -> SALT_VERIFY + 2r


def path_key(key, name: str):
    """Deterministic per-path PRNG key (same CRC fold-in idiom as the
    trainer's per-tile keys), so every weight matrix drifts independently
    but reproducibly."""
    return jax.random.fold_in(key, np.uint32(zlib.crc32(name.encode())))


def has_lifetime(cfg: DeviceConfig) -> bool:
    """True when the preset models any post-training non-ideality."""
    return (cfg.drift_nu != 0.0 or cfg.drift_nu_std != 0.0
            or cfg.read_noise != 0.0 or cfg.prog_noise != 0.0
            or cfg.prog_noise_slope != 0.0)


def apply_lifetime(w_eff, t, key, cfg: DeviceConfig):
    """Read the effective weight array ``w_eff`` (programmed at
    ``cfg.drift_t0``) at absolute time ``t`` seconds after programming.

    Pure and jit-friendly (``t`` may be a traced scalar). Exactly
    ``w_eff`` when ``t == cfg.drift_t0``; ``t`` is clamped below at t0
    (drift is not defined before the reference read)."""
    if not has_lifetime(cfg):
        return w_eff
    seed = fastrng.seed_from_key(key)
    shape = w_eff.shape
    nu = cfg.drift_nu + cfg.drift_nu_std * fastrng.hash_normal(seed, shape, SALT_NU)
    nu = jnp.clip(nu, 0.0, None)
    t = jnp.asarray(t, jnp.float32)
    # (t/t0)^-nu via exp/log: one transcendental pair regardless of nu's
    # per-element spread, and exactly 1.0 at t == t0 (log(1) == 0).
    log_ratio = jnp.log(jnp.maximum(t, cfg.drift_t0) / cfg.drift_t0)
    aged = w_eff * jnp.exp(-nu * log_ratio)
    if cfg.read_noise:
        # read_noise is a conductance-range fraction; w_eff is model-space
        # -> convert by the tensor's amplitude (see module docstring)
        unit = jnp.max(jnp.abs(w_eff))
        aged = aged + cfg.read_noise * unit * fastrng.hash_normal(
            seed, shape, SALT_READ)
    return jnp.where(t == cfg.drift_t0, w_eff, aged).astype(w_eff.dtype)


def program_weights(w_aim, key, cfg: DeviceConfig):
    """Write-and-verify programming of target weights ``w_aim``: returns
    the conductance state actually standing at ``cfg.drift_t0``.

    Round 0 writes the full target with state-dependent error
    ``sigma_p(w) = prog_noise + prog_noise_slope * |w|``; each subsequent
    round reads back through ``read_noise`` and issues a corrective write
    whose error is ``prog_noise_slope * |correction| + c2c floor`` — small
    corrections are cheap to land, so the residual contracts geometrically
    until it hits the read-noise floor (the classic iterative-programming
    curve). ``prog_rounds == 1`` is the pure open-loop model the stats
    tests regress against."""
    if cfg.prog_noise == 0.0 and cfg.prog_noise_slope == 0.0:
        return w_aim
    seed = fastrng.seed_from_key(key)
    shape = w_aim.shape
    sigma0 = cfg.prog_noise + cfg.prog_noise_slope * jnp.abs(w_aim)
    w = w_aim + sigma0 * fastrng.hash_normal(seed, shape, SALT_PROG)
    floor = 0.1 * cfg.prog_noise
    for r in range(1, max(int(cfg.prog_rounds), 1)):
        read = w + cfg.read_noise * fastrng.hash_normal(
            seed, shape, SALT_VERIFY + 2 * r)
        delta = w_aim - read
        sigma_c = floor + cfg.prog_noise_slope * jnp.abs(delta)
        w = w + delta + sigma_c * fastrng.hash_normal(
            seed, shape, SALT_PROG + 2 * r)
    tau = min(cfg.tau_min, cfg.tau_max)
    if cfg.kind == "softbounds" and tau > 0:
        w = jnp.clip(w, -cfg.tau_min, cfg.tau_max)
    return w.astype(w_aim.dtype)


def lifetime_cfg_map(params, tiles, default_cfg: DeviceConfig) -> Dict[str, DeviceConfig]:
    """{path: DeviceConfig} for every *analog* leaf of the merged effective
    params: each TileBank member path maps to its own stack's resolved
    ``device_w`` preset (the conductances that physically hold the weight);
    digital leaves (norms, scalars) are absent — silicon does not drift."""
    out: Dict[str, DeviceConfig] = {}
    for g, paths in tiles.index:
        pol = tiles.policy(g)
        if pol is not None and pol.is_digital:
            continue
        cfg = pol.tile.device_w if pol is not None else default_cfg
        for p in paths:
            out[p] = cfg
    return out


def age_params(params, cfg_map: Dict[str, DeviceConfig], age_s: float, key):
    """Age every analog leaf of a merged effective-params tree to
    ``t = drift_t0 + age_s`` under its own device preset. Leaves without a
    cfg_map entry pass through untouched. ``age_s == 0`` returns leaves
    bit-exactly (the ``t == t0`` branch of ``apply_lifetime``)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: x is None)
    out = []
    for kp, leaf in flat:
        p = path_str(kp)
        cfg = cfg_map.get(p)
        if leaf is None or cfg is None:
            out.append(leaf)
            continue
        out.append(apply_lifetime(leaf, cfg.drift_t0 + float(age_s),
                                  path_key(key, p), cfg))
    return jax.tree_util.tree_unflatten(treedef, out)
