"""Global Drift Compensation (GDC) over effective analog weights.

Conductance drift multiplies every element by ``(t/t0)^-nu``; with a
per-element ``nu`` spread the *mean* decay is still an excellent global
scale model over a tile (Rasch et al.).  GDC estimates that scale the way
hardware does — by pushing a small fixed reference input through the array
and comparing column current sums against the value recorded at
programming time:

  sig(W)  = sum_j | sum_i x_i W_ij |          (x: fixed positive reference)
  alpha   = sig(W_t0) / sig(W_t)              (per weight matrix)
  W_gdc   = alpha * W_t

``sig(W_t0)`` is stored in the checkpoint manifest by the training driver
(``gdc_signatures``); at serve time the same jitted signature runs over the
restored weights.  At ``t == t0`` the restored arrays are bit-identical to
the saved ones, the f32 signature reproduces exactly (json binary64 holds
an f32 exactly), ``alpha == 1.0``, and ``alpha * W`` is a bit-exact no-op
— the token-identity contract of the serving tests.

The signature is chunked over the row axis (``lax.scan`` with a static
trip count of ``GDC_CHUNKS``) so at LM scale the reduction never
materializes more than ``rows/GDC_CHUNKS`` of any matrix's row block at
once, and the loop carries a ``known_trip_count`` annotation the roofline
analyzer and graph contracts can price.
"""
from __future__ import annotations

from typing import Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paths import path_str
from repro.kernels import fastrng

GDC_CHUNKS = 4        # static row-chunk trip count of the signature scan
SALT_REF = 41         # fastrng salt of the fixed reference input
# module-level fixed seed: the reference input is part of the *format* —
# the manifest's stored signatures are only comparable against the exact
# same x, so this never derives from a runtime key.
_REF_SEED = np.array([0x9E3779B9, 0x85EBCA6B], np.uint32)


def reference_input(n: int):
    """Fixed positive reference vector in [0.5, 1): positive so column
    currents do not cancel across rows, deterministic so the t0 and serve
    signatures integrate the exact same probe."""
    return 0.5 + 0.5 * fastrng.hash_uniform(jnp.asarray(_REF_SEED), (n,), SALT_REF)


def weight_signature(w, chunks: int = GDC_CHUNKS):
    """Columnwise current-sum signature of one weight array (f32 scalar).

    ``w`` is read as a (rows, cols) matrix (leading axes flattened into
    rows; 1-D arrays as a single column).  ``chunks > 1`` accumulates the
    column currents over ``chunks`` row blocks under one ``lax.scan`` —
    a counted loop XLA annotates with ``known_trip_count`` — and the
    zero-padded tail rows contribute exactly nothing to the currents."""
    w2 = w.reshape(-1, w.shape[-1]) if w.ndim > 1 else w.reshape(-1, 1)
    rows = w2.shape[0]
    x = reference_input(rows)
    if chunks <= 1 or rows < 2 * chunks:
        return jnp.sum(jnp.abs(x @ w2.astype(jnp.float32)))
    pad = (-rows) % chunks
    if pad:
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
        x = jnp.pad(x, (0, pad))
    step = w2.shape[0] // chunks

    def body(cols, i):
        xs = jax.lax.dynamic_slice_in_dim(x, i * step, step)
        ws = jax.lax.dynamic_slice_in_dim(w2, i * step, step)
        return cols + xs @ ws.astype(jnp.float32), None

    cols, _ = jax.lax.scan(body, jnp.zeros((w2.shape[1],), jnp.float32),
                           jnp.arange(chunks))
    return jnp.sum(jnp.abs(cols))


def signature_tree(params, paths: Iterable[str],
                   chunks: int = GDC_CHUNKS) -> Dict[str, jax.Array]:
    """{path: signature} over the named leaves of ``params`` (pure and
    jit-friendly; one fused reduction per distinct leaf)."""
    want = set(paths)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: x is None)
    out = {}
    for kp, leaf in flat:
        p = path_str(kp)
        if leaf is not None and p in want:
            out[p] = weight_signature(leaf, chunks)
    missing = want - set(out)
    assert not missing, f"signature paths absent from params: {sorted(missing)}"
    return out


def drift_scale(sig0: float, sig_t: float) -> float:
    """Per-matrix GDC scale ``alpha = sig0 / sig_t`` (host floats; exactly
    1.0 when the signatures reproduce bit-identically)."""
    sig_t = float(sig_t)
    if sig_t <= 0.0:
        return 1.0
    return float(sig0) / sig_t


def correct_params(params, sig0: Dict[str, float],
                   chunks: int = GDC_CHUNKS) -> Tuple:
    """Apply GDC to every leaf with a stored t0 signature: recompute the
    aged signature, scale by ``alpha = sig0/sig_t``. Returns
    ``(corrected_params, {path: alpha})``.  ``alpha * w`` with
    ``alpha == 1.0`` is an IEEE-exact identity, so a t0 (unaged) restore
    round-trips bit-exactly through the full GDC path."""
    sig_fn = jax.jit(lambda tree: signature_tree(tree, tuple(sorted(sig0)),
                                                 chunks))
    sig_t = {p: float(v) for p, v in sig_fn(params).items()}
    scales = {p: drift_scale(sig0[p], sig_t[p]) for p in sig0}
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: x is None)
    out = []
    for kp, leaf in flat:
        a = scales.get(path_str(kp))
        if leaf is None or a is None:
            out.append(leaf)
        else:
            out.append((leaf * jnp.asarray(a, leaf.dtype)).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), scales


def correct_in_graph(params, sig0: Dict[str, float],
                     chunks: int = GDC_CHUNKS):
    """In-graph GDC (traced alphas): the form the graph contract lowers —
    calibration reductions + correction + serve step in ONE module."""
    sigs = signature_tree(params, tuple(sorted(sig0)), chunks)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: x is None)
    out = []
    for kp, leaf in flat:
        p = path_str(kp)
        if leaf is None or p not in sigs:
            out.append(leaf)
            continue
        alpha = jnp.asarray(sig0[p], jnp.float32) / jnp.maximum(sigs[p], 1e-30)
        out.append((leaf * alpha.astype(leaf.dtype)).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
