"""Post-training lifetime of analog weights: drift, programming error, GDC.

Training (core/, the paper's subject) ends with a checkpoint of tile state;
*serving* that checkpoint means the effective weights live on physical
conductances that decay over time.  This package models that deployment
half of the story:

  drift  — pure transforms over effective weights: ``program_weights``
           (write-and-verify programming error at t0) and
           ``apply_lifetime`` (conductance drift ``W(t) = W(t0) *
           (t/t0)^-nu`` with per-element nu and read noise), both driven
           by the per-preset lifetime coefficients on ``DeviceConfig``
           and the stateless hash RNG (device-independent replay).
  gdc    — Global Drift Compensation: a columnwise current-sum signature
           of each weight matrix under a fixed reference input; the ratio
           of the t0 signature (stored in the checkpoint manifest) to the
           aged signature is the per-tile scale correction GDC applies at
           load time.

``serving.engine.load_effective_params`` composes the two: age the merged
effective weights to ``t0 + age_s`` per-path under each stack's own device
preset, then (optionally) undo the global scale with GDC.
"""
from .drift import (age_params, apply_lifetime, lifetime_cfg_map,  # noqa: F401
                    path_key, program_weights)
from .gdc import (GDC_CHUNKS, correct_params, drift_scale,  # noqa: F401
                  signature_tree, weight_signature)
