"""Pallas TPU kernels for the analog-training hot spots + jnp oracles.

Modules:
  analog_update.py — fused pulse update (eq. 2 + stochastic rounding + c2c)
  analog_matmul.py — IO-quantized crossbar MVM (paper Table 7 pipeline)
  sp_filter.py     — chopped-EMA SP filter (eq. 12) + telemetry reductions
  ops.py           — jit wrappers, padding, backend dispatch
  ref.py           — pure-jnp oracles (single source of truth for the math)
"""
from . import ops, ref  # noqa: F401
