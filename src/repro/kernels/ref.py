"""Pure-jnp oracles for every Pallas kernel in this package.

These functions are the *single source of truth* for the analog math: the
Pallas kernels are asserted allclose against them in tests, and the rest of
the framework (``repro.core``) calls them through ``repro.kernels.ops`` which
dispatches to the fused kernels when profitable.

Math reference (paper eq. numbers):

  q+(w) = (gamma + rho) * (1 - w / tau_max)          (SoftBoundsReference)
  q-(w) = (gamma - rho) * (1 + w / tau_min)
  F(w)  = (q-(w) + q+(w)) / 2                        (6a)
  G(w)  = (q-(w) - q+(w)) / 2                        (6b)

  Analog Update (2):
    w' = w + dw * F(w) - |dw| * G(w) + b
  realized here as a stochastically-rounded pulse count
    n  = stochastic_round(dw / dw_min)               (b_k, Assumption 3.4)
  optionally capped at +-BL, with per-pulse cycle-to-cycle lognormal-ish
  multiplicative noise aggregated into a single Gaussian term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Response functions (element-wise; all args broadcastable arrays)
# ---------------------------------------------------------------------------


def q_plus(w, gamma, rho, tau_max):
    return (gamma + rho) * (1.0 - w / tau_max)


def q_minus(w, gamma, rho, tau_min):
    return (gamma - rho) * (1.0 + w / tau_min)


def response_fg(w, gamma, rho, tau_min, tau_max):
    """Return (F, G) of eq. (6) for the soft-bounds reference device."""
    qp = q_plus(w, gamma, rho, tau_max)
    qm = q_minus(w, gamma, rho, tau_min)
    return (qm + qp) * 0.5, (qm - qp) * 0.5


# ---------------------------------------------------------------------------
# Fused analog pulse update  (kernel: analog_update.py)
# ---------------------------------------------------------------------------


def analog_update_ref(
    w,
    dw,
    gamma,
    rho,
    ubits,
    zeta,
    *,
    dw_min: float,
    tau_min: float,
    tau_max: float,
    sigma_c2c: float,
    bl: int = 0,
):
    """Apply the Analog Update (2) with stochastic pulse discretization.

    Args:
      w:      current weights (any float dtype; accumulated in f32).
      dw:     desired increment (e.g. ``-lr * grad``).
      gamma:  per-element common response slope (d2d sampled).
      rho:    per-element asymmetry.
      ubits:  uint32 random bits for the stochastic rounding Bernoulli.
      zeta:   standard-normal noise for the aggregated c2c term.
      dw_min: response granularity.
      bl:     max pulses per update (0 = uncapped).

    Returns:
      Updated weights, same dtype as ``w``.
    """
    wf = w.astype(jnp.float32)
    dwf = dw.astype(jnp.float32)
    gam = gamma.astype(jnp.float32)
    rh = rho.astype(jnp.float32)

    # -- pulse count: stochastic rounding of dw / dw_min -------------------
    n_real = dwf / dw_min
    n_floor = jnp.floor(n_real)
    frac = n_real - n_floor
    u = ubits.astype(jnp.float32) * (1.0 / 4294967296.0)  # [0,1)
    n_q = n_floor + (u < frac).astype(jnp.float32)
    if bl and bl > 0:
        n_q = jnp.clip(n_q, -float(bl), float(bl))
    delta = n_q * dw_min  # realized target increment

    # -- response at current state -----------------------------------------
    f, g = response_fg(wf, gam, rh, tau_min, tau_max)
    upd = delta * f - jnp.abs(delta) * g

    # -- aggregated cycle-to-cycle noise ------------------------------------
    # each pulse has multiplicative noise sigma_c2c on its |dw_min * q| step;
    # over |n_q| pulses the aggregate std is dw_min * q_dir * sigma * sqrt(|n|).
    q_dir = jnp.where(delta >= 0.0, q_plus(wf, gam, rh, tau_max), q_minus(wf, gam, rh, tau_min))
    noise = dw_min * sigma_c2c * jnp.sqrt(jnp.abs(n_q)) * q_dir * zeta.astype(jnp.float32)

    w_new = wf + upd + noise
    w_new = jnp.clip(w_new, -tau_min, tau_max)
    return w_new.astype(w.dtype)


def analog_update_expected_ref(w, dw, gamma, rho, *, tau_min, tau_max):
    """Noise-free expectation of the Analog Update (used in theory tests)."""
    wf = w.astype(jnp.float32)
    f, g = response_fg(wf, gamma.astype(jnp.float32), rho.astype(jnp.float32), tau_min, tau_max)
    out = wf + dw.astype(jnp.float32) * f - jnp.abs(dw).astype(jnp.float32) * g
    return jnp.clip(out, -tau_min, tau_max).astype(w.dtype)


# ---------------------------------------------------------------------------
# IO-quantized analog MVM  (kernel: analog_matmul.py)
# ---------------------------------------------------------------------------


def analog_mvm_ref(
    x,
    w,
    noise,
    *,
    inp_res: float,
    inp_bound: float,
    out_res: float,
    out_bound: float,
    out_noise: float,
):
    """Analog crossbar MVM with DAC/ADC quantization (paper Table 7).

    Pipeline: ABS_MAX noise management -> input DAC quantization -> matmul ->
    additive output noise -> ADC clip + quantization -> rescale.

    Args:
      x: (..., K) activations.
      w: (K, N) analog weights.
      noise: standard normal, shape of the output (..., N).
    """
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    # ABS_MAX noise management: scale rows into [-1, 1]
    s = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    s = jnp.maximum(s, 1e-12)
    xn = xf / s
    # input DAC (multiply by the Python-level reciprocal — bit-identical to
    # the Pallas kernel's constant; `x / res` rounds differently at .5 ULP)
    xq = jnp.clip(xn, -inp_bound, inp_bound)
    xq = jnp.round(xq * (1.0 / inp_res)) * inp_res
    # crossbar
    y = xq @ wf
    # output noise + ADC
    y = y + out_noise * noise.astype(jnp.float32)
    y = jnp.clip(y, -out_bound, out_bound)
    y = jnp.round(y * (1.0 / out_res)) * out_res
    return (y * s).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chopped EMA SP filter  (kernel: sp_filter.py)
# ---------------------------------------------------------------------------


def sp_filter_ref(q, p, gamma_p, rho_p, *, eta: float, tau_min: float, tau_max: float):
    """One step of the digital SP-tracking filter (12) plus drift telemetry.

    Returns (q_new, gp_sq_sum, err_sq_sum) where
      q_new       = (1 - eta) * q + eta * p
      gp_sq_sum   = sum(G_p(p)^2)               (convergence metric of Thm 3.7)
      err_sq_sum  = sum((q_new - w_sp)^2)        (SP tracking error; w_sp from
                                                  the corrected eq. (110))
    """
    qf = q.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    gam = gamma_p.astype(jnp.float32)
    rh = rho_p.astype(jnp.float32)
    q_new = (1.0 - eta) * qf + eta * pf
    _, g = response_fg(pf, gam, rh, tau_min, tau_max)
    a_p = gam + rh
    a_m = gam - rh
    w_sp = (a_p - a_m) / (a_p / tau_max + a_m / tau_min)
    gp_sq = jnp.sum(g * g)
    err_sq = jnp.sum((q_new - w_sp) ** 2)
    return q_new.astype(q.dtype), gp_sq, err_sq
