"""IO-quantized analog MVM Pallas kernel (TPU target, interpret-validated).

Simulates a crossbar forward pass with DAC/ADC non-idealities (paper Table 7):
ABS_MAX input scaling, 7-bit input quantization, MXU matmul, additive output
noise, ADC bound clipping and 9-bit output quantization — all fused so the
activation tensor makes a single HBM round trip instead of five.

Layout: grid (M/bm, N/bn, K/bk) with K innermost; the f32 output block acts
as the accumulator (initialized at k==0, epilogue applied at k==K-1), which
keeps the kernel backend-agnostic (no scratch allocation needed in interpret
mode). Block dims default to MXU-aligned (128, 128, 512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCKS = (128, 256, 512)  # (bm, bn, bk)


def _kernel(
    x_ref,      # (bm, bk)
    w_ref,      # (bk, bn)
    s_ref,      # (bm, 1)   per-row ABS_MAX scale
    noise_ref,  # (bm, bn)
    o_ref,      # (bm, bn) f32 accumulator / output
    *,
    nk: int,
    inp_res: float,
    inp_bound: float,
    out_res: float,
    out_bound: float,
    out_noise: float,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    s = s_ref[...].astype(jnp.float32)
    xn = x_ref[...].astype(jnp.float32) / s
    xq = jnp.clip(xn, -inp_bound, inp_bound)
    xq = jnp.round(xq * (1.0 / inp_res)) * inp_res
    o_ref[...] += jnp.dot(xq, w_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = o_ref[...]
        y = y + out_noise * noise_ref[...].astype(jnp.float32)
        y = jnp.clip(y, -out_bound, out_bound)
        y = jnp.round(y * (1.0 / out_res)) * out_res
        o_ref[...] = y * s


def analog_mvm_pallas(
    x,
    w,
    s,
    noise,
    *,
    inp_res: float,
    inp_bound: float,
    out_res: float,
    out_bound: float,
    out_noise: float,
    blocks=DEFAULT_BLOCKS,
    interpret: bool = True,
):
    """x: (M, K), w: (K, N), s: (M, 1) row scales, noise: (M, N) N(0,1).

    Returns f32 (M, N); ``ops.analog_mvm`` handles batching/padding/casting.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm = min(blocks[0], m)
    bn = min(blocks[1], n)
    bk = min(blocks[2], k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, "ops.py pads"
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    kern = functools.partial(
        _kernel,
        nk=nk,
        inp_res=float(inp_res),
        inp_bound=float(inp_bound),
        out_res=float(out_res),
        out_bound=float(out_bound),
        out_noise=float(out_noise),
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, w, s, noise)
