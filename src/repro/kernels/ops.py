"""jit-ready wrappers around the Pallas kernels with pure-jnp fallbacks.

Dispatch policy: on TPU backends the fused Pallas kernels run compiled; on
CPU (this container) the default is the jnp reference path (the Pallas
interpreter executes block-by-block in Python and is only meant for
correctness tests). Both paths consume *identical* random bits so they are
bit-comparable: tests assert allclose between backends for the same key.

All wrappers accept arbitrary-rank inputs; internally tensors are viewed as
2-D and zero-padded to kernel block multiples.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .analog_matmul import DEFAULT_BLOCKS as MVM_BLOCKS
from .analog_matmul import analog_mvm_pallas
from .analog_update import DEFAULT_BLOCK as UPD_BLOCK
from .analog_update import analog_update_pallas
from .sp_filter import sp_filter_pallas

_BACKEND: Optional[str] = None  # None = auto


def set_backend(name: Optional[str]) -> None:
    """Force kernel backend: 'ref', 'pallas', or None for auto."""
    global _BACKEND
    assert name in (None, "ref", "pallas")
    _BACKEND = name


def backend() -> str:
    if _BACKEND is not None:
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad2d(x, bm, bn, fill=0.0):
    m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)), constant_values=fill)
    return x


def _pad3d(x, bm, bn, fill=0.0):
    _, m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, pn)), constant_values=fill)
    return x


def _view2d(x):
    """View an arbitrary-rank array as 2-D (leading dims flattened)."""
    if x.ndim == 0:
        return x.reshape(1, 1)
    if x.ndim == 1:
        return x.reshape(1, -1)
    if x.ndim == 2:
        return x
    return x.reshape(-1, x.shape[-1])


# ---------------------------------------------------------------------------
# analog pulse update
# ---------------------------------------------------------------------------


def analog_update(
    w,
    dw,
    gamma,
    rho,
    key,
    *,
    dw_min: float,
    tau_min: float,
    tau_max: float,
    sigma_c2c: float,
    bl: int = 0,
    interpret: bool = True,
    rng: str = "threefry",
    noise=None,
):
    """Fused analog pulse update; see kernels/ref.analog_update_ref.

    rng='threefry' uses jax.random (paper-grade, bit-stable); rng='hash'
    uses the fused stateless hash (kernels/fastrng.py) — required at LM
    scale where threefry's while-loop blocks GSPMD sharding propagation.
    ``noise`` optionally supplies pre-drawn ``(ubits, zeta)`` at ``w.shape``
    (the grouped engine's fused backend draws one batched stream for a
    whole tile stack); when given, ``key``/``rng`` are ignored and may be
    None.
    """
    kwargs = dict(
        dw_min=dw_min, tau_min=tau_min, tau_max=tau_max, sigma_c2c=sigma_c2c, bl=bl
    )

    def make_noise(shape):
        if noise is not None:
            return noise
        if rng == "hash":
            from . import fastrng

            seed = fastrng.seed_from_key(key)
            return (fastrng.hash_bits(seed, shape, 1),
                    fastrng.hash_normal(seed, shape, 2))
        ku, kz = jax.random.split(key)
        return (jax.random.bits(ku, shape, dtype=jnp.uint32),
                jax.random.normal(kz, shape, dtype=jnp.float32))

    if backend() != "pallas":
        # Pure-jnp path operates on the ORIGINAL shapes: everything is
        # element-wise, and any reshape/pad of a (scan, zero, model)-sharded
        # tile array would force GSPMD to rematerialize it replicated.
        ubits, zeta = make_noise(w.shape)
        return ref.analog_update_ref(w, dw, gamma, rho, ubits, zeta, **kwargs)

    shape = w.shape
    ubits, zeta = make_noise(shape)
    if w.ndim == 3:
        # Tile-stack fast path: keep the member axis as the outermost kernel
        # grid dimension instead of flattening members into one 2-D view.
        m, n = shape[1:]
        bm = min(UPD_BLOCK[0], m)
        bn = min(UPD_BLOCK[1], n)
        pad3 = lambda x, fill=0.0: _pad3d(x, bm, bn, fill=fill)
        out = analog_update_pallas(
            pad3(w), pad3(dw), pad3(gamma, fill=1.0), pad3(rho),
            pad3(ubits, fill=jnp.uint32(1 << 31)), pad3(zeta),
            interpret=interpret, **kwargs,
        )
        return out[:, :m, :n]
    w2 = _view2d(w)
    m, n = w2.shape
    bm = min(UPD_BLOCK[0], m)
    bn = min(UPD_BLOCK[1], n)
    w2 = _pad2d(w2, bm, bn)
    dw2 = _pad2d(_view2d(dw), bm, bn)
    g2 = _pad2d(_view2d(gamma), bm, bn, fill=1.0)
    r2 = _pad2d(_view2d(rho), bm, bn)
    # Draw noise at the ORIGINAL shape so ref and pallas consume identical
    # random bits for any (possibly non-block-multiple) tile, then pad into
    # the kernel grid: ubits=2^31 / zeta=0 keep the dw=0 padding inert.
    u2 = _pad2d(_view2d(ubits), bm, bn, fill=jnp.uint32(1 << 31))
    z2 = _pad2d(_view2d(zeta), bm, bn)
    out = analog_update_pallas(
        w2, dw2, g2, r2, u2, z2, interpret=interpret, **kwargs
    )
    return out[:m, :n].reshape(shape)


# ---------------------------------------------------------------------------
# analog MVM
# ---------------------------------------------------------------------------


def analog_mvm(
    x,
    w,
    key,
    *,
    inp_res: float,
    inp_bound: float,
    out_res: float,
    out_bound: float,
    out_noise: float,
    interpret: bool = True,
):
    """IO-quantized crossbar forward: x (..., K) @ w (K, N)."""
    batch_shape = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    kwargs = dict(
        inp_res=inp_res,
        inp_bound=inp_bound,
        out_res=out_res,
        out_bound=out_bound,
        out_noise=out_noise,
    )
    if backend() == "pallas":
        bm = min(MVM_BLOCKS[0], m)
        bn = min(MVM_BLOCKS[1], n)
        bk = min(MVM_BLOCKS[2], k)
        s = jnp.maximum(jnp.max(jnp.abs(x2.astype(jnp.float32)), axis=-1, keepdims=True), 1e-12)
        xp = _pad2d(x2, bm, bk)
        wp = _pad2d(w, bk, bn)
        sp = _pad2d(s, bm, 1, fill=1.0)
        # noise at the original output shape (bit-identical to the ref path),
        # zero-padded into the kernel grid
        noise = _pad2d(jax.random.normal(key, (m, n), dtype=jnp.float32),
                       xp.shape[0], wp.shape[1])
        out = analog_mvm_pallas(xp, wp, sp, noise, interpret=interpret, **kwargs)
        out = out[:m, :n].astype(x.dtype)
    else:
        noise = jax.random.normal(key, (m, n), dtype=jnp.float32)
        out = ref.analog_mvm_ref(x2, w, noise, **kwargs)
    return out.reshape(*batch_shape, n)


# ---------------------------------------------------------------------------
# SP filter
# ---------------------------------------------------------------------------


def sp_filter(
    q,
    p,
    gamma,
    rho,
    *,
    eta: float,
    tau_min: float,
    tau_max: float,
    interpret: bool = True,
):
    """EMA tracking update (12) + telemetry. Returns (q_new, gp_sq, err_sq)."""
    shape = q.shape
    q2 = _view2d(q)
    m, n = q2.shape
    bm = min(256, m)
    bn = min(512, n)
    q2 = _pad2d(q2, bm, bn)
    p2 = _pad2d(_view2d(p), bm, bn)
    g2 = _pad2d(_view2d(gamma), bm, bn, fill=1.0)
    r2 = _pad2d(_view2d(rho), bm, bn)
    if backend() == "pallas":
        q_new, gp, err = sp_filter_pallas(
            q2, p2, g2, r2, eta=eta, tau_min=tau_min, tau_max=tau_max,
            interpret=interpret,
        )
        # padded gamma=1, rho=0 regions contribute 0 to gp but (q-w_sp)^2 = 0
        # there as well since q=p=0 and w_sp=0.
    else:
        q_new, gp, err = ref.sp_filter_ref(
            q2, p2, g2, r2, eta=eta, tau_min=tau_min, tau_max=tau_max
        )
    return q_new[: m, : n].reshape(shape), gp, err
