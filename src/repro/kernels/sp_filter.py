"""Chopped-EMA SP-tracking filter Pallas kernel (TPU target).

Implements the digital side of E-RIDER's tracking loop in one fused pass:
the first-order IIR low-pass filter Q <- (1-eta) Q + eta P (paper eq. 12,
Lemma 3.10) together with the two telemetry reductions the convergence
metric (14) needs: sum G_p(P)^2 and the SP tracking error sum (Q' - w_sp)^2.

Partial sums are emitted per grid row and reduced by the thin ops wrapper —
this keeps the kernel free of cross-block accumulation hazards on both the
TPU and interpret backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)


def _kernel(
    q_ref,
    p_ref,
    gamma_ref,
    rho_ref,
    qout_ref,
    gp_ref,   # (1, 1) partial sum of G_p(P)^2 for this block
    err_ref,  # (1, 1) partial sum of (Q' - w_sp)^2 for this block
    *,
    eta: float,
    tau_min: float,
    tau_max: float,
):
    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    gam = gamma_ref[...].astype(jnp.float32)
    rho = rho_ref[...].astype(jnp.float32)

    q_new = (1.0 - eta) * q + eta * p
    qout_ref[...] = q_new.astype(qout_ref.dtype)

    qp = (gam + rho) * (1.0 - p * (1.0 / tau_max))
    qm = (gam - rho) * (1.0 + p * (1.0 / tau_min))
    g = (qm - qp) * 0.5

    a_p = gam + rho
    a_m = gam - rho
    w_sp = (a_p - a_m) / (a_p * (1.0 / tau_max) + a_m * (1.0 / tau_min))

    gp_ref[0, 0] = jnp.sum(g * g)
    err_ref[0, 0] = jnp.sum((q_new - w_sp) ** 2)


def sp_filter_pallas(
    q,
    p,
    gamma,
    rho,
    *,
    eta: float,
    tau_min: float,
    tau_max: float,
    block=DEFAULT_BLOCK,
    interpret: bool = True,
):
    """Returns (q_new, gp_sq_sum, err_sq_sum). 2-D inputs, identical shapes."""
    m, n = q.shape
    bm = min(block[0], m)
    bn = min(block[1], n)
    assert m % bm == 0 and n % bn == 0, "ops.py pads"
    gm, gn = m // bm, n // bn

    kern = functools.partial(
        _kernel, eta=float(eta), tau_min=float(tau_min), tau_max=float(tau_max)
    )
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    q_new, gp_parts, err_parts = pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((m, n), q.dtype),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
            jax.ShapeDtypeStruct((gm, gn), jnp.float32),
        ),
        grid=(gm, gn),
        in_specs=[spec] * 4,
        out_specs=(spec, scalar_spec, scalar_spec),
        interpret=interpret,
    )(q, p, gamma, rho)
    return q_new, jnp.sum(gp_parts), jnp.sum(err_parts)
