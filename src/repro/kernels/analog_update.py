"""Fused analog pulse-update Pallas kernel (TPU target, interpret-validated).

The analog update (paper eq. 2) touches W, dW, per-element device params
(gamma, rho) and two noise streams — 6 weight-sized arrays — and is purely
element-wise: arithmetic intensity << 1 FLOP/byte, i.e. **memory bound**.
An unfused jnp implementation performs ~15 HBM round trips (one per jnp op);
this kernel performs exactly one read of each operand and one write of the
output per element, streamed through VMEM in (block_m, block_n) tiles.

The stochastic-rounding Bernoulli consumes pre-generated uint32 bits and the
aggregated cycle-to-cycle noise consumes a standard-normal operand; see
DESIGN.md §3 (TPU adaptation) for why RNG is an operand rather than
``pltpu.prng_*`` (no CPU-interpret rule; bit-exact testability).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile: 6 f32 operands + 1 output at (256, 512) = ~3.7 MiB,
# comfortably inside a 16 MiB VMEM budget; last dim is a multiple of 128
# (lane width) and second-to-last a multiple of 8 (sublane width).
DEFAULT_BLOCK = (256, 512)


def _kernel(
    w_ref,
    dw_ref,
    gamma_ref,
    rho_ref,
    ubits_ref,
    zeta_ref,
    out_ref,
    *,
    dw_min: float,
    tau_min: float,
    tau_max: float,
    sigma_c2c: float,
    bl: int,
):
    w = w_ref[...].astype(jnp.float32)
    dw = dw_ref[...].astype(jnp.float32)
    gam = gamma_ref[...].astype(jnp.float32)
    rho = rho_ref[...].astype(jnp.float32)

    inv_dwmin = 1.0 / dw_min
    n_real = dw * inv_dwmin
    n_floor = jnp.floor(n_real)
    frac = n_real - n_floor
    u = ubits_ref[...].astype(jnp.float32) * (1.0 / 4294967296.0)
    n_q = n_floor + (u < frac).astype(jnp.float32)
    if bl and bl > 0:
        n_q = jnp.clip(n_q, -float(bl), float(bl))
    delta = n_q * dw_min

    qp = (gam + rho) * (1.0 - w * (1.0 / tau_max))
    qm = (gam - rho) * (1.0 + w * (1.0 / tau_min))
    f = (qm + qp) * 0.5
    g = (qm - qp) * 0.5
    upd = delta * f - jnp.abs(delta) * g

    q_dir = jnp.where(delta >= 0.0, qp, qm)
    noise = (dw_min * sigma_c2c) * jnp.sqrt(jnp.abs(n_q)) * q_dir * zeta_ref[...].astype(jnp.float32)

    w_new = jnp.clip(w + upd + noise, -tau_min, tau_max)
    out_ref[...] = w_new.astype(out_ref.dtype)


def analog_update_pallas(
    w,
    dw,
    gamma,
    rho,
    ubits,
    zeta,
    *,
    dw_min: float,
    tau_min: float,
    tau_max: float,
    sigma_c2c: float,
    bl: int = 0,
    block=DEFAULT_BLOCK,
    interpret: bool = True,
):
    """Fused analog update on 2-D tiles or 3-D tile stacks.

    2-D ``(m, n)`` inputs tile over a ``(m//bm, n//bn)`` grid; 3-D
    ``(k, m, n)`` inputs (a TileBank class stack, member axis leading) add
    the stack axis as the outermost grid dimension so each member streams
    through VMEM independently — no flatten/restack on the host side.
    ``ops.analog_update`` handles reshaping/padding of arbitrary trees.
    """
    assert w.ndim in (2, 3), "kernel operates on 2-D tiles or 3-D stacks"
    m, n = w.shape[-2:]
    bm = min(block[0], m)
    bn = min(block[1], n)
    assert m % bm == 0 and n % bn == 0, "ops.py pads to block multiples"

    kern = functools.partial(
        _kernel,
        dw_min=float(dw_min),
        tau_min=float(tau_min),
        tau_max=float(tau_max),
        sigma_c2c=float(sigma_c2c),
        bl=int(bl),
    )
    if w.ndim == 2:
        grid = (m // bm, n // bn)
        spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    else:
        grid = (w.shape[0], m // bm, n // bn)
        spec = pl.BlockSpec((1, bm, bn), lambda k, i, j: (k, i, j))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=spec,
        interpret=interpret,
    )(w, dw, gamma, rho, ubits, zeta)
