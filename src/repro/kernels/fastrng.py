"""Fused stateless per-element RNG for analog-update noise at LM scale.

``jax.random.bits``/``normal`` (threefry) lower to a 5-round while loop over
whole arrays; under GSPMD the loop blocks backward sharding propagation, so
the bit arrays materialize *replicated* — hundreds of MB of HBM per tile per
step. This module derives randomness from a murmur3-style integer hash of
(linear index, seed, salt): a short elementwise chain that XLA fuses into
the consumer (zero extra HBM traffic) and GSPMD shards with it.

Statistical quality is far above the needs of stochastic pulse rounding and
c2c noise (verified empirically in tests/test_properties.py); the
paper-grade threefry path remains the default (TileConfig.rng).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_TWO_PI = 6.283185307179586


def _finalize(x):
    """murmur3 fmix32 finalizer (elementwise, u32)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_bits(seed, shape, salt: int):
    """seed: (2,) uint32; returns uint32 array of ``shape``.

    The linear index is built from per-dimension broadcasted_iotas (not a 1-D
    iota + reshape) so GSPMD can shard the whole chain with its consumer.
    """
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for d in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(jnp.uint32, shape, d) * jnp.uint32(stride)
        stride *= int(shape[d])
    x = idx * jnp.uint32(0xCC9E2D51) + seed[0] + jnp.uint32((salt * 0x9E3779B9) & 0xFFFFFFFF)
    x = _finalize(x)
    x = x ^ (seed[1] + jnp.uint32(salt & 0xFFFFFFFF))
    return _finalize(x)


def hash_uniform(seed, shape, salt: int):
    """[0, 1) f32."""
    return hash_bits(seed, shape, salt).astype(jnp.float32) * (1.0 / 4294967296.0)


def hash_normal(seed, shape, salt: int):
    """Standard normal via Box-Muller over two hashed uniforms."""
    u1 = hash_uniform(seed, shape, salt)
    u2 = hash_uniform(seed, shape, salt + 0x5BD1)
    r = jnp.sqrt(-2.0 * jnp.log(jnp.maximum(u1, 1e-12)))
    return r * jnp.cos(_TWO_PI * u2)


def seed_from_key(key):
    """PRNG key -> (2,) uint32 seed scalars."""
    data = jax.random.key_data(key).astype(jnp.uint32)
    return data.reshape(-1)[:2]
