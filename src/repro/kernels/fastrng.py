"""Fused stateless per-element RNG for analog-update noise at LM scale.

``jax.random.bits``/``normal`` (threefry) lower to a 5-round while loop over
whole arrays; under GSPMD the loop blocks backward sharding propagation, so
the bit arrays materialize *replicated* — hundreds of MB of HBM per tile per
step. This module derives randomness from a murmur3-style integer hash of
(linear index, seed, salt): a short elementwise chain that XLA fuses into
the consumer (zero extra HBM traffic) and GSPMD shards with it.

Statistical quality is far above the needs of stochastic pulse rounding and
c2c noise (verified empirically in tests/test_properties.py); the
paper-grade threefry path remains the default (TileConfig.rng).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_SQRT2 = 1.4142135623730951


def _finalize(x):
    """murmur3 fmix32 finalizer (elementwise, u32)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_bits(seed, shape, salt: int):
    """seed: (2,) uint32; returns uint32 array of ``shape``.

    The linear index is built from per-dimension broadcasted_iotas (not a 1-D
    iota + reshape) so GSPMD can shard the whole chain with its consumer.
    """
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for d in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(jnp.uint32, shape, d) * jnp.uint32(stride)
        stride *= int(shape[d])
    x = idx * jnp.uint32(0xCC9E2D51) + seed[0] + jnp.uint32((salt * 0x9E3779B9) & 0xFFFFFFFF)
    x = _finalize(x)
    x = x ^ (seed[1] + jnp.uint32(salt & 0xFFFFFFFF))
    return _finalize(x)


def hash_uniform(seed, shape, salt: int):
    """[0, 1) f32."""
    return hash_bits(seed, shape, salt).astype(jnp.float32) * (1.0 / 4294967296.0)


# f32 just below 1: clamping |2u - 1| here caps samples at ~5.4 sigma and,
# critically, keeps erfinv off the exact +/-1 poles — without it, lattice
# values within ~6e-8 of the ends round to +/-1.0f and the inverse CDF
# returns +/-inf (once every ~1e7 draws: hours at toy scale, minutes at LM
# scale, and a single inf poisons W with NaN through the pulse update).
_ONE_MINUS_EPS = 0.99999994
_LN2 = 0.6931471805599453

# Giles (2012), "Approximating the erfinv function": single-precision
# central (w < 5) and tail polynomials in w = -log(1 - x^2).
_ERFINV_CENTRAL = (3.43273939e-07, -3.5233877e-06, -4.39150654e-06,
                   0.00021858087, -0.00125372503, -0.00417768164,
                   0.246640727, 1.50140941)
_ERFINV_TAIL = (0.000100950558, 0.00134934322, -0.00367342844,
                0.00573950773, -0.0076224613, 0.00943887047,
                1.00167406, 2.83297682)


def _fast_neg_log(y):
    """-log(y) for f32 y in (0, 1] via exponent/mantissa bitcast split.

    ``jax.lax.erf_inv``'s dominant cost is its internal log; this bitcast
    log (Mineiro's fastlog2: linear exponent term + rational mantissa
    correction, |err| < 3e-4) is ~5x cheaper and the erfinv polynomial
    contracts the error further (~5e-5 in the returned sample — far inside
    the f32 noise floor of the pulse math that consumes it).
    """
    bi = jax.lax.bitcast_convert_type(y, jnp.int32)
    mant = jax.lax.bitcast_convert_type(
        (bi & 0x007FFFFF) | 0x3F000000, jnp.float32)  # mantissa/2 in [.5, 1)
    log2y = (bi.astype(jnp.float32) * 1.1920928955078125e-07
             - 124.22551499 - 1.498030302 * mant
             - 1.72587999 / (0.3520887068 + mant))
    return -_LN2 * log2y


def hash_normal(seed, shape, salt: int):
    """Standard normal via the inverse CDF over one hashed uniform.

    One hash draw + a fused-friendly erfinv (fast bitcast log + Giles'
    polynomials) is ~5x cheaper than Box-Muller's log/cos pair and stays
    the exact inverse-CDF transform to ~5e-5 absolute, so distribution
    tests that pass for threefry pass here too. The +0.5 centers the
    uint32 lattice inside (0, 1).
    """
    u = (hash_bits(seed, shape, salt).astype(jnp.float32) + 0.5) * (
        1.0 / 4294967296.0)
    x = jnp.clip(2.0 * u - 1.0, -_ONE_MINUS_EPS, _ONE_MINUS_EPS)
    w = _fast_neg_log(1.0 - x * x)
    wc = w - 2.5
    p1 = jnp.float32(2.81022636e-08)
    for c in _ERFINV_CENTRAL:
        p1 = p1 * wc + jnp.float32(c)
    ws = jnp.sqrt(jnp.maximum(w, 5.0)) - 3.0
    p2 = jnp.float32(-0.000200214257)
    for c in _ERFINV_TAIL:
        p2 = p2 * ws + jnp.float32(c)
    return _SQRT2 * jnp.where(w < 5.0, p1, p2) * x


def seed_from_key(key):
    """PRNG key -> (2,) uint32 seed scalars."""
    data = jax.random.key_data(key).astype(jnp.uint32)
    return data.reshape(-1)[:2]
