"""AST linter for repo-specific JAX pitfalls (pass 2 of check_graphs).

Five rules, each targeting a bug class that type checkers and generic
linters miss because the code is *valid Python* — it just does the wrong
thing under ``jax.jit``:

``host-rng``
    ``np.random.*`` / stdlib ``random.*`` calls. Host RNG inside traced
    code is baked in as a constant at trace time — every step reuses the
    same "random" draw. Allowed under ``repro/data/`` (host-side corpus
    synthesis runs eagerly by design).
``prngkey-reuse``
    The same ``PRNGKey(<literal>)`` seed constructed at two different
    sites in one module: the streams are identical, so "independent"
    noise is perfectly correlated.
``tracer-sync``
    Host syncs in hot paths: ``.item()`` anywhere; ``float()`` / ``int()``
    / ``bool()`` applied directly to a ``jnp.*`` call's result; and
    ``np.asarray`` / ``np.array`` inside the hot packages (``core``,
    ``kernels``, ``models``) — each one blocks until the device finishes
    and kills dispatch pipelining (PR 7 removed exactly this from the
    serve loop).
``mutable-default-config``
    A mutable default (``[]`` / ``{}`` / ``set()`` or a
    ``default_factory`` of list/dict/set) on a *static config* dataclass
    — one that is frozen or named ``*Config``. Static configs are hashed
    into jit caches; a mutable field either breaks hashing or, worse,
    mutates without retriggering a trace.
``module-level-jnp``
    ``jnp.*`` calls at module scope: device computation (and backend
    initialization) as an import side effect. Constants belong inside
    functions or as ``np`` data.

Escapes — both are deliberate-host-code markers, not suppressions of
real bugs:

* a function whose body contains its own ``import numpy`` is host-side
  post-processing by construction; ``tracer-sync`` and ``host-rng`` are
  skipped inside it (see ``core/zs.py::pulses_to_target``);
* a line containing ``graphlint: allow`` suppresses any finding on it.

``lint_source`` is pure text -> findings (unit-testable);
``run_lint`` walks a source root.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

RULES = ("host-rng", "prngkey-reuse", "tracer-sync",
         "mutable-default-config", "module-level-jnp")

PRAGMA = "graphlint: allow"

# packages where a hidden device->host sync is a perf bug, not a wart
HOT_PACKAGES = ("repro/core/", "repro/kernels/", "repro/models/")
# packages allowed to use host RNG (eager, host-side by design)
HOST_RNG_OK = ("repro/data/",)

_MUTABLE_FACTORIES = ("list", "dict", "set")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute/name chain ('np.random.normal'), or
    None for anything fancier (calls, subscripts)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _has_local_numpy_import(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Import):
            if any(a.name in ("numpy", "numpy.random") for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if (node.module or "").split(".")[0] == "numpy":
                return True
    return False


class _Aliases:
    """What do 'np', 'jnp', 'random'... mean in this module?"""

    def __init__(self, tree: ast.Module):
        self.numpy: set = set()
        self.jnp: set = set()
        self.std_random: set = set()
        self.prngkey: set = set()      # names that ARE PRNGKey
        self.jax_random: set = set()   # names that are jax.random
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        self.numpy.add(name)
                    elif a.name == "jax.numpy":
                        self.jnp.add(a.asname or "jnp")
                    elif a.name == "random":
                        self.std_random.add(name)
                    elif a.name == "jax.random":
                        self.jax_random.add(a.asname or "random")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                for a in node.names:
                    name = a.asname or a.name
                    if mod == "jax" and a.name == "numpy":
                        self.jnp.add(name)
                    elif mod == "jax" and a.name == "random":
                        self.jax_random.add(name)
                    elif mod == "jax.random" and a.name == "PRNGKey":
                        self.prngkey.add(name)
                    elif mod == "numpy" and a.name == "random":
                        self.numpy.add(name)  # "from numpy import random"


def _is_prngkey_call(call: ast.Call, al: _Aliases) -> bool:
    chain = _attr_chain(call.func)
    if chain is None:
        return False
    if chain in al.prngkey or chain == "jax.random.PRNGKey":
        return True
    head, _, tail = chain.rpartition(".")
    return tail == "PRNGKey" and (head in al.jax_random or head == "jax.random")


def _dataclass_meta(cls: ast.ClassDef) -> Tuple[bool, bool]:
    """(is_dataclass, is_frozen) from the decorator list."""
    is_dc = frozen = False
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        chain = _attr_chain(target) or ""
        if chain.split(".")[-1] != "dataclass":
            continue
        is_dc = True
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                    frozen = frozen or bool(kw.value.value)
    return is_dc, frozen


def _mutable_default(value: ast.AST) -> Optional[str]:
    """Describe a mutable default expression, or None if it's fine."""
    if isinstance(value, (ast.List, ast.Dict, ast.Set)):
        return f"literal {type(value).__name__.lower()} default"
    if isinstance(value, ast.Call):
        chain = _attr_chain(value.func) or ""
        if chain.split(".")[-1] in _MUTABLE_FACTORIES and not value.args:
            return f"{chain}() default"
        if chain.split(".")[-1] == "field":
            for kw in value.keywords:
                if kw.arg != "default_factory":
                    continue
                f = kw.value
                fname = _attr_chain(f) or ""
                if fname.split(".")[-1] in _MUTABLE_FACTORIES:
                    return f"default_factory={fname}"
                if isinstance(f, ast.Lambda) and _mutable_default(f.body):
                    return "default_factory=lambda returning a mutable"
    return None


def lint_source(source: str, path: str) -> List[LintFinding]:
    """Lint one module's source text. ``path`` is repo-relative and is
    used both for reporting and for package-scoped rules."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, "parse-error", str(e.msg))]

    lines = source.splitlines()
    al = _Aliases(tree)
    norm = path.replace(os.sep, "/")
    hot = any(p in norm for p in HOT_PACKAGES)
    rng_ok = any(p in norm for p in HOST_RNG_OK)
    findings: List[LintFinding] = []

    def emit(node: ast.AST, rule: str, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if 0 < line <= len(lines) and PRAGMA in lines[line - 1]:
            return
        findings.append(LintFinding(path, line, rule, message))

    # --- function bodies marked host-side by a local numpy import -------
    host_fns: List[ast.AST] = [
        n for n in ast.walk(tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        and _has_local_numpy_import(n)]
    host_nodes = set()
    for fn in host_fns:
        for n in ast.walk(fn):
            host_nodes.add(id(n))

    # --- per-node rules -------------------------------------------------
    prng_seeds: Dict[object, int] = {}  # literal seed -> first lineno
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        in_host_fn = id(node) in host_nodes
        chain = _attr_chain(node.func) or ""

        # host-rng: np.random.* / random.*
        if not in_host_fn and not rng_ok:
            parts = chain.split(".")
            if len(parts) >= 2 and parts[0] in al.numpy and parts[1] == "random":
                emit(node, "host-rng",
                     f"{chain}() is host RNG: traced code bakes the draw in "
                     "as a constant (use jax.random with a threaded key)")
            elif len(parts) == 2 and parts[0] in al.std_random:
                emit(node, "host-rng",
                     f"{chain}() is host RNG: traced code bakes the draw in "
                     "as a constant (use jax.random with a threaded key)")

        # prngkey-reuse: same literal seed at two sites
        if _is_prngkey_call(node, al) and node.args \
                and isinstance(node.args[0], ast.Constant):
            seed = node.args[0].value
            if seed in prng_seeds:
                emit(node, "prngkey-reuse",
                     f"PRNGKey({seed!r}) already constructed at line "
                     f"{prng_seeds[seed]}: identical seeds give identical "
                     "streams (split one key instead)")
            else:
                prng_seeds[seed] = node.lineno

        # tracer-sync
        if not in_host_fn:
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                emit(node, "tracer-sync",
                     ".item() blocks on the device and returns a Python "
                     "scalar: under jit it fails; outside it kills dispatch "
                     "pipelining")
            if hot and chain.split(".")[0] in al.numpy \
                    and chain.split(".")[-1] in ("asarray", "array"):
                emit(node, "tracer-sync",
                     f"{chain}() in a hot package forces a device->host "
                     "transfer (use jnp, or mark the function host-side "
                     "with a local `import numpy`)")
            if isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int", "bool") \
                    and node.args and isinstance(node.args[0], ast.Call):
                inner = _attr_chain(node.args[0].func) or ""
                if inner.split(".")[0] in al.jnp:
                    emit(node, "tracer-sync",
                         f"{node.func.id}({inner}(...)) syncs on the device "
                         "result (keep it an array, or compute with plain "
                         "Python/np scalars)")

    # --- mutable-default-config ----------------------------------------
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        is_dc, frozen = _dataclass_meta(node)
        if not is_dc or not (frozen or node.name.endswith("Config")):
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                why = _mutable_default(stmt.value)
                if why:
                    target = getattr(stmt.target, "id", "<field>")
                    emit(stmt, "mutable-default-config",
                         f"static config {node.name}.{target} has a mutable "
                         f"default ({why}): unhashable as a jit-static, and "
                         "mutation won't retrigger tracing (use a tuple / "
                         "frozen value)")

    # --- module-level-jnp -----------------------------------------------
    def scan_toplevel(body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(stmt, (ast.If, ast.Try)):
                for sub in ast.iter_child_nodes(stmt):
                    if isinstance(sub, ast.stmt):
                        scan_toplevel([sub])
                continue
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    chain = _attr_chain(node.func) or ""
                    if chain.split(".")[0] in al.jnp:
                        emit(node, "module-level-jnp",
                             f"{chain}() at module scope runs device "
                             "computation at import time (move it inside "
                             "the function that needs it)")

    scan_toplevel(tree.body)

    return findings


def run_lint(root: str) -> List[LintFinding]:
    """Lint every ``*.py`` under ``root`` (deterministic order)."""
    findings: List[LintFinding] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            full = os.path.join(dirpath, fname)
            rel = os.path.relpath(full, os.path.dirname(root.rstrip("/")))
            with open(full, encoding="utf-8") as f:
                findings.extend(lint_source(f.read(), rel))
    return findings
