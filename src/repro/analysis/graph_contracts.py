"""The contract registry: build, lower and compile every jitted entrypoint.

Each entrypoint the repo's perf guarantees live in gets a builder that
constructs a smoke-sized instance (tiny shapes — the *structure* of the
optimized HLO is what the contracts assert, and XLA's rewrites are
shape-independent at this granularity) and returns its compiled HLO text.
``run_contract`` marries a builder to its :class:`GraphContract`.

Builders accept a ``mutant`` hook used by the mutation tests (and by
``tools/check_graphs.py --mutate`` to prove the gate bites):

* ``"restack"``       — re-stacks every class stack slice-by-slice after
  the update (exactly the PR-5 data movement the scanned engine removed);
* ``"host_transfer"`` — plants a ``jax.debug.print`` host callback;
* ``"f64"``           — routes the loss through an f64 round-trip (lowered
  under ``enable_x64`` so the promotion actually materializes);
* ``"no_donate"``     — drops buffer donation.

All lowering happens on CPU; contracts assert structure (ops, dtypes,
aliasing, trip counts) and trip-weighted costs, none of which need real
hardware.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .contracts import ContractResult, GraphContract, check_hlo

MUTANTS = ("restack", "host_transfer", "f64", "no_donate")


# --------------------------------------------------------------------------
# mutation hooks
# --------------------------------------------------------------------------

def _mutate_restack(tree):
    """Rebuild every (C, n, *member) class-stack leaf with a per-slice
    restack — the rank-(member+2) concatenate the scanned engine's
    class-keyed storage eliminated. Slices get distinct epsilon offsets so
    XLA cannot fold the concatenate back into a no-op copy."""
    def r(leaf):
        if getattr(leaf, "ndim", 0) >= 4 and jnp.issubdtype(leaf.dtype,
                                                            jnp.floating):
            parts = [leaf[:, i] + jnp.asarray(i * 1e-30, leaf.dtype)
                     for i in range(leaf.shape[1])]
            return jnp.stack(parts, axis=1)
        return leaf
    return jax.tree.map(r, tree)


def _mutate_f64(x):
    """f64 round-trip (a real one only under enable_x64)."""
    return jax.tree.map(
        lambda l: (l.astype(jnp.float64) * 2.0).astype(l.dtype) / 2.0
        if jnp.issubdtype(l.dtype, jnp.floating) else l, x)


@contextlib.contextmanager
def _lowering_ctx(mutant: Optional[str]):
    if mutant == "f64":
        from jax.experimental import enable_x64
        with enable_x64():
            yield
    else:
        yield


# --------------------------------------------------------------------------
# smoke fixtures
# --------------------------------------------------------------------------

def _quad_loss(params, batch, rng):
    return sum(jnp.sum(v ** 2) for _, v in sorted(params.items())), {}


def _train_setup(backend: str):
    """3-block wq/wo (two spec-split groups, one 2-member scan class) plus
    an odd singleton — the smallest instance exercising scan-over-classes,
    spec-aware grouping AND the fused flatten path."""
    from repro.core.device import DeviceConfig
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.plan import AnalogPlan, TilePolicy
    from repro.core.tile import TileConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig

    dev = DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1,
                       sigma_c2c=0.05)
    extra = {"rng": "hash", "update_backend": "fused"} \
        if backend == "fused" else {}
    tile = TileConfig(algorithm="erider", device_p=dev, device_w=dev,
                      lr_p=0.5, lr_w=0.5, gamma=0.1, eta=0.1, chopper_p=0.1,
                      **extra)
    cfg = TrainerConfig(
        tile=tile,
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1))
    tr = AnalogTrainer(
        _quad_loss, cfg,
        plan=AnalogPlan.of(("**", TilePolicy(tile, name="contract"))))
    params = {}
    for i in range(3):
        params[f"l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
        params[f"l{i}/attn/wo"] = 0.1 * jnp.ones((8, 8))
    params["odd"] = 0.1 * jnp.ones((4, 24))
    state = tr.init(jax.random.PRNGKey(0), params)
    return tr, state


def _serve_setup():
    from repro.configs import get_config
    from repro.models.lm import LM
    from repro.serving import EngineConfig
    from repro.serving.sampling import FeedBuilder

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(42))
    ecfg = EngineConfig(lanes=4, page_size=8, num_pages=33, max_len=64)
    paged = model.init_paged_cache(ecfg.lanes, ecfg.num_pages,
                                  ecfg.page_size, ecfg.max_len)
    feed = FeedBuilder(cfg)(np.zeros((1, 16), np.int32))
    return model, params, ecfg, paged, feed


def _compile(fn, args, donate, mutant: Optional[str]) -> str:
    if mutant == "no_donate":
        donate = ()
    with _lowering_ctx(mutant):
        jfn = jax.jit(fn, donate_argnums=donate)
        return jfn.lower(*args).compile().as_text()


# --------------------------------------------------------------------------
# entrypoint builders: name -> optimized HLO text
# --------------------------------------------------------------------------

def _wrap_step(step, mutant: Optional[str]):
    """Apply a mutation inside a train_step-shaped fn(state, batch)."""
    if mutant == "restack":
        def mutated(state, batch):
            new_state, metrics = step(state, batch)
            bank = new_state["tiles"]
            from repro.core.tile import TileBank
            new_state["tiles"] = TileBank.from_classes(
                {c: _mutate_restack(arr)
                 for c, arr in bank.classes.items()},
                bank.index, bank.class_index, bank.policies)
            return new_state, metrics
        return mutated
    if mutant == "host_transfer":
        def mutated(state, batch):
            new_state, metrics = step(state, batch)
            jax.debug.print("contract-mutation loss={l}", l=metrics["loss"])
            return new_state, metrics
        return mutated
    if mutant == "f64":
        def mutated(state, batch):
            new_state, metrics = step(state, batch)
            metrics = dict(metrics, loss=_mutate_f64(metrics["loss"]))
            return new_state, metrics
        return mutated
    return step


def build_train_step_scanned(mutant: Optional[str] = None) -> str:
    tr, state = _train_setup("vmap")
    return _compile(_wrap_step(tr.train_step, mutant),
                    (state, jnp.zeros(())), (0,), mutant)


def build_train_step_fused(mutant: Optional[str] = None) -> str:
    tr, state = _train_setup("fused")
    return _compile(_wrap_step(tr.train_step, mutant),
                    (state, jnp.zeros(())), (0,), mutant)


def build_begin_step(mutant: Optional[str] = None) -> str:
    """Phase 1 alone (chopper draw / Q-tilde sync) over the donated bank —
    the graph `launch/train` warm-starts before the first full step."""
    from repro.core import algorithms as alg
    from repro.core.tile import TileBank
    from repro.core.trainer import _vmap_tile

    tr, state = _train_setup("vmap")
    bank = state["tiles"]

    def begin(bank: TileBank, key_raw):
        key = jax.random.wrap_key_data(key_raw)
        begun = tr._grouped_apply(
            bank,
            lambda gcfg: _vmap_tile(lambda ts, k: alg.begin_step(ts, k, gcfg)),
            key)
        out = TileBank.from_classes(begun, bank.index, bank.class_index,
                                    bank.policies)
        if mutant == "restack":
            out = TileBank.from_classes(
                {c: _mutate_restack(arr) for c, arr in out.classes.items()},
                out.index, out.class_index, out.policies)
        if mutant == "host_transfer":
            leaf = jax.tree_util.tree_leaves(out.classes)[0]
            jax.debug.print("contract-mutation {c}", c=leaf.sum())
        if mutant == "f64":
            out = TileBank.from_classes(
                {c: _mutate_f64(arr) for c, arr in out.classes.items()},
                out.index, out.class_index, out.policies)
        return out

    key_raw = jax.random.key_data(jax.random.PRNGKey(1))
    return _compile(begin, (bank, key_raw), (0,), mutant)


def build_prefill_commit(mutant: Optional[str] = None) -> str:
    model, params, ecfg, paged, feed = _serve_setup()
    from repro.serving.sampling import sample_greedy

    prompt_len, page_size = 16, ecfg.page_size

    def prefill_commit(params, feed, paged, row, lane):
        dense = model.init_cache(1, prompt_len)
        logits, dense = model.prefill(params, feed, dense)
        tok = sample_greedy(logits)
        if mutant == "host_transfer":
            jax.debug.print("contract-mutation {t}", t=tok.sum())
        if mutant == "f64":
            paged = _mutate_f64(paged)
        if mutant == "restack":
            paged = _mutate_restack(paged)
        out = model.commit_prefill(paged, dense, row, lane,
                                   prompt_len=prompt_len,
                                   page_size=page_size)
        return tok, out

    row = jnp.zeros((ecfg.table_width,), jnp.int32)
    return _compile(prefill_commit, (params, feed, paged, row, 0), (2,),
                    mutant)


def build_serve_step_lanes(mutant: Optional[str] = None) -> str:
    model, params, ecfg, paged, _ = _serve_setup()

    def step_fn(params, last, cache, table, pos, live):
        toks, cache = model.serve_step_lanes(params, last, cache, table, pos,
                                             live)
        if mutant == "host_transfer":
            jax.debug.print("contract-mutation {t}", t=toks.sum())
        if mutant == "f64":
            cache = _mutate_f64(cache)
        if mutant == "restack":
            cache = _mutate_restack(cache)
        return toks, cache, pos + 1

    last = jnp.zeros((ecfg.lanes, 1), jnp.int32)
    table = jnp.zeros((ecfg.lanes, ecfg.table_width), jnp.int32)
    pos = jnp.zeros((ecfg.lanes,), jnp.int32)
    live = jnp.ones((ecfg.lanes,), bool)
    return _compile(step_fn, (params, last, paged, table, pos, live), (2,),
                    mutant)


def build_serve_step_lanes_gdc(mutant: Optional[str] = None) -> str:
    """serve_step_lanes behind in-graph Global Drift Compensation: the
    chunked signature reductions (counted ``lax.scan`` loops — the
    trip-count rule prices them, not the trip-1 fallback), the per-matrix
    alpha division and the correction multiply lower into ONE module with
    the decode step."""
    model, params, ecfg, paged, _ = _serve_setup()
    from repro.core.paths import path_str
    from repro.lifetime import gdc as lgdc

    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    # every matrix-shaped leaf gets calibrated (the serve path calibrates
    # exactly the analog leaves; the structure is identical)
    sig0 = {path_str(kp): 1.0 for kp, leaf in flat
            if getattr(leaf, "ndim", 0) >= 2}

    def step_fn(params, last, cache, table, pos, live):
        corrected = lgdc.correct_in_graph(params, sig0)
        toks, cache = model.serve_step_lanes(corrected, last, cache, table,
                                             pos, live)
        if mutant == "host_transfer":
            jax.debug.print("contract-mutation {t}", t=toks.sum())
        if mutant == "f64":
            cache = _mutate_f64(cache)
        if mutant == "restack":
            cache = _mutate_restack(cache)
        return toks, cache, pos + 1

    last = jnp.zeros((ecfg.lanes, 1), jnp.int32)
    table = jnp.zeros((ecfg.lanes, ecfg.table_width), jnp.int32)
    pos = jnp.zeros((ecfg.lanes,), jnp.int32)
    live = jnp.ones((ecfg.lanes,), bool)
    return _compile(step_fn, (params, last, paged, table, pos, live), (2,),
                    mutant)


def build_prefill_commit_batch(mutant: Optional[str] = None) -> str:
    """The PR-9 bucketed multi-lane prefill: 2 rows padded to a 16-token
    length bucket, masked in-graph, K/V scattered straight into the rows'
    pages, last valid position sampled in-graph."""
    model, params, ecfg, paged, _ = _serve_setup()
    from repro.serving.sampling import sample_greedy

    def prefill_batch(params, tokens, paged, tables, lanes, starts, lengths,
                      fresh):
        if mutant == "f64":
            paged = _mutate_f64(paged)
        if mutant == "restack":
            paged = _mutate_restack(paged)
        logits, out = model.prefill_commit_batch(
            params, tokens, paged, tables, lanes, starts, lengths, fresh)
        tok = sample_greedy(logits)
        if mutant == "host_transfer":
            jax.debug.print("contract-mutation {t}", t=tok.sum())
        return tok, out

    B, Cb = 2, 16
    tokens = jnp.zeros((B, Cb), jnp.int32)
    tables = jnp.zeros((B, ecfg.table_width), jnp.int32)
    lanes = jnp.arange(B, dtype=jnp.int32)
    starts = jnp.zeros((B,), jnp.int32)
    lengths = jnp.full((B,), Cb, jnp.int32)
    fresh = jnp.ones((B,), bool)
    return _compile(prefill_batch,
                    (params, tokens, paged, tables, lanes, starts, lengths,
                     fresh), (2,), mutant)


ENTRYPOINTS: Dict[str, Callable[[Optional[str]], str]] = {
    "train_step_scanned": build_train_step_scanned,
    "train_step_fused": build_train_step_fused,
    "begin_step": build_begin_step,
    "prefill_commit": build_prefill_commit,
    "serve_step_lanes": build_serve_step_lanes,
    "serve_step_lanes_gdc": build_serve_step_lanes_gdc,
    "prefill_commit_batch": build_prefill_commit_batch,
}


# --------------------------------------------------------------------------
# the contracts themselves
# --------------------------------------------------------------------------
# HBM ceilings are ~1.5x the measured smoke-instance cost (stable: the
# fixtures are deterministic); tightening them is free, loosening them
# trips the baseline diff. Collectives are zero on the single-device
# lowering by construction.

_TRAIN_DTYPES = ("pred", "s32", "u32", "f32")
_SERVE_DTYPES = ("pred", "s32", "u32", "f32")

# copy ceiling note: the scan engines carry one layout copy of a class
# stack (f32[2,3,8,8] = 1536 B on the smoke fixture, lax.scan putting the
# scan axis first), so the train ceiling is 2048, one stack + slack —
# a second stack materializing (donation regression) trips hbm/donation.
# serving max_restacks=2 is the two RoPE rotate-half concatenates
# (rank 4, dims={3}); a cache restack adds more and trips.
CONTRACTS: Dict[str, GraphContract] = {
    "train_step_scanned": GraphContract(
        name="train_step_scanned",
        description="grouped engine, scan over same-structure classes: "
                    "zero per-step restacks of class stacks, donated state "
                    "round-trips in place",
        allowed_dtypes=_TRAIN_DTYPES,
        min_aliased=10,          # measured 26
        max_copy_bytes=2048,     # measured 1536 (scan-carry layout copy)
        max_hbm_bytes=1.5e6,     # measured 770k
    ),
    "train_step_fused": GraphContract(
        name="train_step_fused",
        description="fused batched pulse-update backend: one flattened "
                    "update per class, hash RNG (no threefry while-loops "
                    "beyond the scan), same zero-restack guarantee",
        allowed_dtypes=_TRAIN_DTYPES,
        min_aliased=10,          # measured 26
        max_copy_bytes=2048,     # measured 1536
        max_hbm_bytes=4.0e5,     # measured 192k (4x under the vmap path)
    ),
    "begin_step": GraphContract(
        name="begin_step",
        description="phase-1 chopper/Qt sync over the donated TileBank",
        allowed_dtypes=_TRAIN_DTYPES,
        min_aliased=10,          # measured 24
        max_copy_bytes=2048,     # measured 1536
        max_hbm_bytes=3.5e5,     # measured 170k
    ),
    "prefill_commit": GraphContract(
        name="prefill_commit",
        description="batch-1 dense prefill + in-graph first-token sample + "
                    "paged KV commit: donated page pools, no host sync "
                    "between sample and scatter",
        allowed_dtypes=_SERVE_DTYPES,
        max_restacks=2,          # RoPE rotate-half concats
        min_aliased=2,           # measured 2 (donated page pools)
        max_copy_bytes=196608,   # measured 131072 (embed-table copy)
        max_hbm_bytes=1.4e7,     # measured 7.0M
    ),
    "prefill_commit_batch": GraphContract(
        name="prefill_commit_batch",
        description="bucketed multi-lane masked prefill: donated page "
                    "pools, in-graph length masking and first-token "
                    "sampling, no dense-cache round trip",
        allowed_dtypes=_SERVE_DTYPES,
        max_restacks=2,          # RoPE rotate-half concats
        min_aliased=2,           # donated page pools
        max_copy_bytes=98304,    # measured 67584 (one KV pool)
        max_hbm_bytes=2.2e7,     # measured 15.2M
    ),
    "serve_step_lanes_gdc": GraphContract(
        name="serve_step_lanes_gdc",
        description="GDC-corrected decode step: chunked signature "
                    "reductions (counted scans — every while carries or "
                    "derives a trip count), in-graph alpha correction, "
                    "then the same donated-cache decode guarantees",
        allowed_dtypes=_SERVE_DTYPES,
        max_restacks=2,          # RoPE rotate-half concats
        min_aliased=2,           # donated page pools
        max_copy_bytes=98304,    # measured 67584 (same KV-pool copy)
        max_hbm_bytes=1.4e7,     # measured 9.3M (decode + signature sweep)
    ),
    "serve_step_lanes": GraphContract(
        name="serve_step_lanes",
        description="one decode step across all lanes at per-lane "
                    "positions: donated cache, zero host transfers "
                    "(a callback stalls every lane), f32-only math",
        allowed_dtypes=_SERVE_DTYPES,
        max_restacks=2,          # RoPE rotate-half concats
        min_aliased=2,           # measured 2
        max_copy_bytes=98304,    # measured 67584 (one KV pool)
        max_hbm_bytes=1.1e7,     # measured 5.4M
    ),
}

assert set(CONTRACTS) == set(ENTRYPOINTS)


def run_contract(name: str, mutant: Optional[str] = None) -> ContractResult:
    hlo = ENTRYPOINTS[name](mutant)
    return check_hlo(CONTRACTS[name], hlo)


def run_contracts(names: Optional[Iterable[str]] = None,
                  mutant: Optional[str] = None) -> List[ContractResult]:
    return [run_contract(n, mutant) for n in (names or sorted(CONTRACTS))]
