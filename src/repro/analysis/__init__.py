"""Static-analysis layer: graph contracts over lowered HLO + an AST linter.

Two passes, both driven by ``tools/check_graphs.py``:

* **Pass 1 — graph contracts** (`contracts.py` + `graph_contracts.py`):
  every jitted entrypoint the repo's perf story depends on (the scanned
  and fused ``train_step``, grouped ``begin_step``, serving
  ``prefill_commit`` / ``serve_step_lanes``) is lowered and compiled on
  CPU and its *optimized* HLO is asserted against a declarative
  :class:`~repro.analysis.contracts.GraphContract` — zero restack
  concatenates, donation aliasing actually applied, no host transfers,
  a dtype allowlist (never f64), and ceilings on collective bytes and
  trip-weighted HBM traffic.
* **Pass 2 — AST lint** (`astlint.py`): repo-specific JAX pitfalls in
  the source itself — host RNG reachable from traced code, PRNGKey
  literal reuse, tracer host-syncs in hot paths, mutable defaults in
  static config dataclasses, module-level jnp computation.
"""
from .contracts import ContractResult, GraphContract, check_hlo
from .astlint import LintFinding, run_lint

__all__ = ["GraphContract", "ContractResult", "check_hlo", "LintFinding",
           "run_lint"]
