"""Declarative structural contracts over optimized HLO text.

A :class:`GraphContract` states what the *compiled* graph of one jitted
entrypoint must look like — the invariants the repo's perf and numerics
story depends on but which, until now, lived only in commit messages:

* **no restacks** — the scanned tile engine consumes class-keyed storage
  in place; a refactor that reintroduces per-step ``jnp.stack`` of the
  class stacks shows up as rank-N ``concatenate`` ops (PR 5 had 17 of
  them; PR 6 removed them all).
* **donation applied** — tile state is donated and must actually alias
  (``input_output_alias`` in the module header), with no full-stack
  ``copy`` sneaking the round trip back in.
* **no host transfers** — ``infeed``/``outfeed``/``send``/``recv`` and
  host-callback ``custom-call``s stall every lane of the serving engine.
* **dtype allowlist** — ``f64`` anywhere in the module is an accidental
  promotion (the analog update path is f32 by contract; one f64 op
  silently doubles HBM traffic and breaks TPU parity); each contract
  lists exactly the dtypes it may use.
* **cost ceilings** — trip-weighted HBM bytes and collective bytes per
  step, priced by ``roofline/hlo_cost.py``, must stay under per-contract
  ceilings.
* **trip counts** — every ``while`` must carry a
  ``known_trip_count`` annotation, or the cost model (and the ceilings
  above) silently misprice the program.

``check_hlo`` is pure text -> result: it never compiles anything, so the
unit tests can feed it synthetic HLO. Building and compiling the real
entrypoints lives in ``graph_contracts.py``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

from repro.roofline import hlo_cost
from repro.roofline.hlo_common import (DTYPE_BYTES, HOST_TRANSFER_OPS,
                                       SHAPE_RE, TRIP_RE, shape_bytes)

# dtypes a contract may allow; f64/c64/c128 are never allowed (the repo
# trains and serves in <= 32-bit; a 64-bit op is always an accident)
FORBIDDEN_DTYPES = frozenset(("f64", "c64", "c128"))
DEFAULT_ALLOWED_DTYPES = frozenset(
    ("pred", "s4", "u4", "s8", "u8", "s16", "u16", "s32", "u32", "s64",
     "u64", "f16", "bf16", "f32", "token", "opaque"))

_ALIAS_MARK = "input_output_alias={"
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\(")
_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


@dataclasses.dataclass(frozen=True)
class GraphContract:
    """Structural invariants for one jitted entrypoint's optimized HLO."""

    name: str
    description: str = ""
    # concatenate ops of result rank >= restack_rank count as restacks
    # (class stacks are (C, n, *member): a restack of 2-D members is a
    # rank-4 concatenate; legitimate grad stacking enters at rank 3)
    restack_rank: int = 4
    max_restacks: int = 0
    # donation: the module header must alias >= min_aliased outputs, and
    # no single `copy` op may move more than max_copy_bytes (a full-size
    # copy of a donated class stack means aliasing silently failed)
    require_donation: bool = True
    min_aliased: int = 1
    max_copy_bytes: int = 1 << 62
    # host transfers: infeed/outfeed/send/recv always violate; custom-call
    # targets violate unless allowlisted (CPU lowering of the repo's
    # entrypoints uses none — a callback shows up immediately)
    allowed_custom_calls: Tuple[str, ...] = ()
    allowed_dtypes: Tuple[str, ...] = tuple(sorted(DEFAULT_ALLOWED_DTYPES))
    # per-step ceilings priced by the trip-count-aware cost model
    max_collective_bytes: float = 0.0
    max_hbm_bytes: float = float("inf")
    require_trip_counts: bool = True

    def __post_init__(self):
        bad = set(self.allowed_dtypes) & FORBIDDEN_DTYPES
        if bad:
            raise ValueError(
                f"contract {self.name!r} allowlists forbidden dtypes {sorted(bad)}")
        unknown = set(self.allowed_dtypes) - set(DTYPE_BYTES)
        if unknown:
            raise ValueError(
                f"contract {self.name!r} allowlists unknown dtypes {sorted(unknown)}")

    def limits_json(self) -> Dict:
        """The loosenable knobs, for baseline drift detection."""
        return {
            "restack_rank": self.restack_rank,
            "max_restacks": self.max_restacks,
            "require_donation": self.require_donation,
            "min_aliased": self.min_aliased,
            "max_copy_bytes": self.max_copy_bytes,
            "allowed_custom_calls": sorted(self.allowed_custom_calls),
            "allowed_dtypes": sorted(self.allowed_dtypes),
            "max_collective_bytes": self.max_collective_bytes,
            "max_hbm_bytes": self.max_hbm_bytes,
            "require_trip_counts": self.require_trip_counts,
        }


@dataclasses.dataclass
class ContractResult:
    name: str
    violations: List[Dict[str, str]] = dataclasses.field(default_factory=list)
    stats: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> Dict:
        return {"name": self.name, "ok": self.ok,
                "violations": list(self.violations), "stats": dict(self.stats)}


def _result_rank(type_str: str) -> int:
    """Rank of an instruction result (max over tuple elements)."""
    best = 0
    for m in SHAPE_RE.finditer(type_str):
        dims = m.group(2)
        best = max(best, dims.count(",") + 1 if dims else 0)
    return best


def _aliased_outputs(hlo: str) -> int:
    start = hlo.find(_ALIAS_MARK)
    if start < 0:
        return 0
    # the map nests braces ({output-index}: (arg, {arg-index}, kind)) —
    # scan to the matching close instead of regex-balancing
    i = start + len(_ALIAS_MARK)
    depth = 1
    while i < len(hlo) and depth:
        if hlo[i] == "{":
            depth += 1
        elif hlo[i] == "}":
            depth -= 1
        i += 1
    return len(_ALIAS_ENTRY_RE.findall(hlo[start + len(_ALIAS_MARK):i]))


def check_hlo(contract: GraphContract, hlo: str) -> ContractResult:
    """Assert ``contract`` against one optimized-HLO module's text."""
    res = ContractResult(contract.name)
    comps = hlo_cost.parse_module(hlo)

    restacks = []
    copies_max = 0
    host_ops = []
    dtypes_seen = set()
    whiles = 0
    whiles_unannotated = []
    for comp in comps.values():
        for instr in comp.instrs:
            dtypes_seen.update(
                m.group(1) for m in SHAPE_RE.finditer(instr.type_str)
                if m.group(1) in DTYPE_BYTES)
            if instr.op == "concatenate" \
                    and _result_rank(instr.type_str) >= contract.restack_rank:
                restacks.append(f"{comp.name}/{instr.name}")
            elif instr.op == "copy":
                copies_max = max(copies_max, shape_bytes(instr.type_str))
            elif instr.op in HOST_TRANSFER_OPS:
                host_ops.append(f"{comp.name}/{instr.name} [{instr.op}]")
            elif instr.op == "custom-call":
                tm = _TARGET_RE.search(instr.rest)
                target = tm.group(1) if tm else "<unknown>"
                if target not in contract.allowed_custom_calls:
                    host_ops.append(
                        f"{comp.name}/{instr.name} [custom-call {target}]")
            elif instr.op == "while":
                whiles += 1
                # a loop is "annotated" if XLA stamped known_trip_count OR
                # the counted-loop structure lets hlo_cost derive the count
                # (what the roofline pricer actually uses) — only loops the
                # pricer would fall back to trip-1 on violate the contract
                if not TRIP_RE.search(instr.rest) and \
                        hlo_cost.derive_trip_count(instr, comp, comps) is None:
                    whiles_unannotated.append(f"{comp.name}/{instr.name}")

    cost = hlo_cost.analyze_hlo(hlo)
    aliased = _aliased_outputs(hlo)

    def violate(rule: str, detail: str) -> None:
        res.violations.append({"rule": rule, "detail": detail})

    if len(restacks) > contract.max_restacks:
        violate("restack",
                f"{len(restacks)} concatenate op(s) of rank >= "
                f"{contract.restack_rank} (contract allows "
                f"{contract.max_restacks}): {', '.join(restacks[:5])}")
    if contract.require_donation and aliased < contract.min_aliased:
        violate("donation",
                f"input-output aliasing covers {aliased} output(s); contract "
                f"requires >= {contract.min_aliased} (donated buffers are "
                "not round-tripping in place)")
    if copies_max > contract.max_copy_bytes:
        violate("copy",
                f"largest copy op moves {copies_max} bytes "
                f"(> {contract.max_copy_bytes}): a donated stack is being "
                "materialized instead of aliased")
    if host_ops:
        violate("host-transfer",
                f"{len(host_ops)} host-transfer op(s): "
                f"{', '.join(host_ops[:5])}")
    bad_dtypes = dtypes_seen - set(contract.allowed_dtypes)
    if bad_dtypes:
        violate("dtype",
                f"dtype(s) {sorted(bad_dtypes)} outside the contract "
                f"allowlist {sorted(set(contract.allowed_dtypes) - set(('token', 'opaque')))}")
    if cost.coll_bytes > contract.max_collective_bytes:
        violate("collective-bytes",
                f"{cost.coll_bytes:.0f} collective bytes/step "
                f"(> {contract.max_collective_bytes:.0f})")
    if cost.bytes > contract.max_hbm_bytes:
        violate("hbm-bytes",
                f"{cost.bytes:.0f} trip-weighted HBM bytes/step "
                f"(> {contract.max_hbm_bytes:.0f})")
    if contract.require_trip_counts and whiles_unannotated:
        violate("trip-count",
                f"{len(whiles_unannotated)} while loop(s) without "
                f"known_trip_count: {', '.join(whiles_unannotated[:5])}")

    res.stats = {
        "restacks": len(restacks),
        "aliased_outputs": aliased,
        "max_copy_bytes": copies_max,
        "host_transfer_ops": len(host_ops),
        "dtypes": sorted(dtypes_seen),
        "whiles": whiles,
        "whiles_unannotated": len(whiles_unannotated),
        "hbm_bytes": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "flops": cost.flops,
    }
    return res


def loosened(current: GraphContract, baseline_limits: Dict) -> List[str]:
    """Which knobs of ``current`` are looser than the baseline recorded?
    Returns human-readable descriptions (empty = nothing loosened)."""
    cur = current.limits_json()
    out = []

    def check_max(key):
        if key in baseline_limits and cur[key] > baseline_limits[key]:
            out.append(f"{key} raised {baseline_limits[key]} -> {cur[key]}")

    for key in ("max_restacks", "max_copy_bytes", "max_collective_bytes",
                "max_hbm_bytes"):
        check_max(key)
    if "restack_rank" in baseline_limits \
            and cur["restack_rank"] > baseline_limits["restack_rank"]:
        out.append(f"restack_rank raised {baseline_limits['restack_rank']} "
                   f"-> {cur['restack_rank']} (fewer concats count)")
    if "min_aliased" in baseline_limits \
            and cur["min_aliased"] < baseline_limits["min_aliased"]:
        out.append(f"min_aliased lowered {baseline_limits['min_aliased']} "
                   f"-> {cur['min_aliased']}")
    for key in ("require_donation", "require_trip_counts"):
        if baseline_limits.get(key) and not cur[key]:
            out.append(f"{key} disabled")
    for key in ("allowed_dtypes", "allowed_custom_calls"):
        extra = set(cur[key]) - set(baseline_limits.get(key, cur[key]))
        if extra:
            out.append(f"{key} grew by {sorted(extra)}")
    return out
