"""Analog tile abstraction: one model weight mapped onto analog arrays.

A *tile* bundles everything one analog cross-bar weight needs:
  W   — main analog array (always present)
  P   — auxiliary analog array (fast/residual array; TT's "A")
  Qd  — digital SP-tracking array (RIDER eq. 12 EMA; TT-v2's hidden H lives
        in the same slot-style bundle as ``H``)
  Qt  — "fake" analog copy of Q (E-RIDER's periodically-synced reference)
  H   — TT-v2 digital hidden/transfer accumulator
  c   — chopper sign (scalar, +-1)
  t   — step counter
  scale — tile-to-model weight scale (model weight = scale * analog weight)
  dev_p/dev_w — per-element device parameters of the P / W arrays

Unused slots are ``None`` (a fixed structure per algorithm, so everything
stays jit-stable). All arrays share the weight's shape, which is what makes
the ZeRO-style (data+model)-axis sharding of tile state legal: every analog
update is element-local.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .device import PRESETS, DeviceConfig, DeviceParams, abstract_device, sample_device

ALGORITHMS = ("sgd", "ttv1", "ttv2", "agad", "residual", "rider", "erider")


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Static hyper-parameters of an analog tile (hashable, non-pytree)."""

    algorithm: str = "erider"
    device_p: DeviceConfig = PRESETS["reram_om"]
    device_w: DeviceConfig = PRESETS["reram_om"]
    lr_p: float = 0.5        # alpha multiplier (fast / gradient array)
    lr_w: float = 0.05       # beta multiplier (transfer / main array)
    gamma: float = 0.1       # residual mixing scale
    eta: float = 0.5         # EMA stepsize (12)
    chopper_p: float = 0.05  # chopper flip probability (17)
    transfer_every: int = 1  # TT transfer period
    threshold: float = 1.0   # TT-v2 transfer threshold, units of dw_min(W)
    bl: int = 0              # pulse-train length cap (0 = uncapped)
    pulse_mode: str = "fused"
    target_range: float = 0.6  # fraction of tau used by the initial weights
    min_weight_range: float = 0.1  # scale floor: assume |w| grows to >= this
    state_dtype: Any = jnp.float32
    # Store per-element device params (gamma, rho) as arrays (True, paper-
    # repro fidelity) or regenerate them each step from a per-tile seed
    # (False, LM-scale: saves 8-16 bytes/param of HBM for a memory-bound
    # recompute — the d2d field is a physical constant, not training state).
    store_device: bool = True
    rng: str = "threefry"  # threefry (paper-grade) | hash (fused, LM scale)
    # Gradient-to-pulse normalization (AIHWKit "auto granularity" analogue):
    # 'absmean' rescales each tile's gradient by its mean |g| so the fast
    # learning rate counts *pulses per element per step* — device-
    # granularity-invariant. 'none' uses raw model gradients.
    grad_norm: str = "none"
    # Pulse-update execution backend for the grouped engine:
    # 'vmap' (reference) runs the per-tile update under jax.vmap with
    # per-tile threefry/hash keys; 'fused' runs one batched update over the
    # whole (n, *member) stack with noise drawn as per-tile fastrng hash
    # streams — the form that feeds the batched Pallas kernel on TPU and
    # skips threefry's while-loops on CPU. 'fused' is bit-identical to
    # 'vmap' with rng='hash' (tested); it ignores ``rng``.
    update_backend: str = "vmap"
    # Buffered (thresholded) W-transfer for residual/rider/erider: the
    # (18b) increment accumulates in a digital buffer and is emitted as
    # whole pulses (AIHWKit forget-buffer semantics — what the paper's
    # experiments run). Essential on low-state devices where a continuous
    # sub-pulse transfer stochastically fires huge dw_min pulses.
    buffered_transfer: bool = False
    # Per-step diagnostic tile metrics. 'full' (default) reports pulse
    # counts plus the SP-tracking diagnostics (gp_sq, sp_err) — each is an
    # extra full pass + reduction over every tile, ~a third of a grouped
    # erider step on CPU. 'pulses' keeps only pulse counts; 'none' skips
    # all per-tile metrics (LM-scale / benchmark configs).
    metrics: str = "full"

    def __post_init__(self):
        assert self.algorithm in ALGORITHMS, self.algorithm
        assert self.metrics in ("full", "pulses", "none"), self.metrics
        assert self.update_backend in ("vmap", "fused"), self.update_backend
        if self.update_backend == "fused":
            # the batched backend pre-draws (ubits, zeta) once per stack;
            # the sequential pulse train draws per pulse and can't consume it
            assert self.pulse_mode == "fused", \
                "update_backend='fused' requires pulse_mode='fused'"


def _needs(algorithm: str, buffered: bool = False) -> Dict[str, bool]:
    a = algorithm
    return dict(
        P=a != "sgd",
        # Qd doubles as AGAD's dynamic reference estimate (readout low-pass)
        Qd=a in ("residual", "rider", "erider", "agad"),
        Qt=a == "erider",
        H=a in ("ttv2", "agad") or (buffered and a in ("residual", "rider", "erider")),
        chopper=a in ("agad", "erider"),
        dev_p=a != "sgd",
    )


class TileState(dict):
    """dict-backed pytree; fixed key set per algorithm."""


jax.tree_util.register_pytree_with_keys(
    TileState,
    lambda d: (tuple((jax.tree_util.DictKey(k), d[k]) for k in sorted(d)),
               tuple(sorted(d))),
    lambda keys, vals: TileState(zip(keys, vals)),
)


def init_tile(
    key,
    w0: jnp.ndarray,
    cfg: TileConfig,
    sp_estimate: Optional[jnp.ndarray] = None,
) -> TileState:
    """Create a tile for a digitally-initialized weight ``w0``.

    The model weight is ``scale * analog``; ``scale`` maps w0 into
    ``target_range * tau`` of the device dynamic range.
    """
    need = _needs(cfg.algorithm, cfg.buffered_transfer)
    kp, kw, kq = jax.random.split(key, 3)
    dt = cfg.state_dtype

    tau = min(cfg.device_w.tau_min, cfg.device_w.tau_max)
    max_abs = jnp.maximum(
        jnp.max(jnp.abs(w0.astype(jnp.float32))), cfg.min_weight_range
    )
    scale = max_abs / (cfg.target_range * tau)

    w = (w0.astype(jnp.float32) / scale).astype(dt)
    shape = w0.shape

    st = TileState(
        W=w,
        t=jnp.zeros((), jnp.int32),
        scale=scale.astype(jnp.float32),
        dev_w=sample_device(kw, shape, cfg.device_w) if cfg.store_device else None,
        seed_w=None if cfg.store_device else jax.random.key_data(kw).astype(jnp.uint32),
        P=jnp.zeros(shape, dt) if need["P"] else None,
        Qd=None,
        Qt=None,
        H=jnp.zeros(shape, jnp.float32) if need["H"] else None,
        c=jnp.ones((), jnp.float32) if need["chopper"] else None,
        prog=jnp.zeros((), jnp.int32) if cfg.algorithm == "erider" else None,
        dev_p=(sample_device(kp, shape, cfg.device_p)
               if (need["dev_p"] and cfg.store_device) else None),
        seed_p=(None if (cfg.store_device or not need["dev_p"])
                else jax.random.key_data(kp).astype(jnp.uint32)),
    )
    if need["Qd"]:
        q0 = jnp.zeros(shape, dt) if sp_estimate is None else sp_estimate.astype(dt)
        st["Qd"] = q0
        if need["Qt"]:
            st["Qt"] = jnp.copy(q0)  # distinct buffer (donation safety)
        if cfg.algorithm == "residual" and sp_estimate is not None:
            # Two-stage semantics (Alg. 4): the ZS calibration physically
            # drives the P device TO its (estimated) symmetric point before
            # training starts — so P begins at the estimate, not at zero.
            st["P"] = jnp.copy(q0)
    return st


def abstract_tile(shape, cfg: TileConfig) -> TileState:
    """ShapeDtypeStruct skeleton of a tile (dry-run lowering)."""
    need = _needs(cfg.algorithm, cfg.buffered_transfer)
    dt = cfg.state_dtype

    def arr(dtype=dt):
        return jax.ShapeDtypeStruct(shape, dtype)

    seed = jax.ShapeDtypeStruct((2,), jnp.uint32)
    st = TileState(
        W=arr(),
        t=jax.ShapeDtypeStruct((), jnp.int32),
        scale=jax.ShapeDtypeStruct((), jnp.float32),
        dev_w=abstract_device(shape, dt) if cfg.store_device else None,
        seed_w=None if cfg.store_device else seed,
        P=arr() if need["P"] else None,
        Qd=arr() if need["Qd"] else None,
        Qt=arr() if need["Qt"] else None,
        H=arr(jnp.float32) if need["H"] else None,
        c=jax.ShapeDtypeStruct((), jnp.float32) if need["chopper"] else None,
        prog=jax.ShapeDtypeStruct((), jnp.int32) if cfg.algorithm == "erider" else None,
        dev_p=(abstract_device(shape, dt) if (need["dev_p"] and cfg.store_device) else None),
        seed_p=(None if (cfg.store_device or not need["dev_p"]) else seed),
    )
    return st


def expected_pulses(dw, dw_min: float, bl: int = 0):
    """Expected pulse count of an update (telemetry for Fig. 4)."""
    n = jnp.abs(dw.astype(jnp.float32)) / dw_min
    if bl:
        n = jnp.minimum(n, float(bl))
    return jnp.sum(n)


# ---------------------------------------------------------------------------
# Batched tile engine: shape-grouped stacks of tiles
# ---------------------------------------------------------------------------


def group_name(shape, dtype, tag: str = "", ptag: str = "") -> str:
    """Stable group key for all tiles of one (shape, dtype, rule template,
    policy): "g64x64_float32_nM_prider".

    ``tag`` is the sharding-rule template tag of the member weights
    (``distributed.sharding.template_tag``; e.g. "nM" for attention wq,
    "Mn" for wo) — keying on it keeps stacks from mixing partition rules,
    so the stacked spec can always carry the members' model axis. ``ptag``
    is the [a-z0-9]+ TilePolicy tag (``core.plan.TilePolicy.tag``) and is
    empty for single-policy plans, so single-policy group keys are
    byte-identical to the pre-AnalogPlan layout. The name is parseable
    (see ``parse_group_name``) so a checkpoint written in any grouped
    layout can be matched back against per-tile or re-keyed stacks.
    """
    dims = "x".join(str(int(d)) for d in shape)
    base = f"g{dims}_{jnp.dtype(dtype).name}"
    if tag:
        base += f"_{tag}"
    if ptag:
        base += f"_p{ptag}"
    return base


def parse_group_name(name: str) -> Optional[tuple]:
    """Inverse of ``group_name``:
    "g64x64_float32_nM_prider" -> ((64, 64), "float32", "nM", "rider");
    the template and policy tags are "" for layouts that predate them
    ("g64x64_float32" -> ((64, 64), "float32", "", "")). Returns None if
    ``name`` is not a group key."""
    m = re.match(
        r"^g(\d+(?:x\d+)*)_([A-Za-z0-9]+?)(?:_([MDns]+))?(?:_p([a-z0-9]+))?$",
        name)
    if not m:
        return None
    shape = tuple(int(d) for d in m.group(1).split("x"))
    return shape, m.group(2), m.group(3) or "", m.group(4) or ""


def class_name(group_names) -> str:
    """Scan-class key: '+'-joined member group names (member order). A
    single-group class is keyed by the group name itself; '+' is not in the
    ``group_name`` charset, so the two namespaces cannot collide."""
    return "+".join(group_names)


def parse_class_name(name: str) -> tuple:
    """Inverse of ``class_name``: member group names, in stack order."""
    return tuple(name.split("+"))


def class_partition(groups: Dict[str, "TileState"], index, policies=None):
    """Partition grouped tile states into *scan classes*: groups with
    identical tree structure, leaf shapes/dtypes AND TilePolicy, which can
    therefore share one scanned/vmapped update graph and one storage stack.

    The sharding-rule template tag is deliberately NOT part of the
    signature — an "nM" and an "Mn" group of the same shape run the same
    program and live in the same class; only their sharding specs differ
    (``distributed.sharding`` re-derives those per member group).

    Returns the class index: ((class_name, (group, ...)), ...), classes
    sorted by name, members in ``index`` order.
    """
    policies = policies or {}
    by_sig: Dict[Any, list] = {}
    for g, _ in index:
        leaves, treedef = jax.tree_util.tree_flatten(groups[g])
        sig = (str(treedef),
               tuple((tuple(l.shape), jnp.dtype(l.dtype).name) for l in leaves),
               policies.get(g))
        by_sig.setdefault(sig, []).append(g)
    return tuple(sorted(
        (class_name(gs), tuple(gs)) for gs in by_sig.values()))


def _stack_states(states):
    """Stack same-structure TileStates along a new leading axis. Handles
    ShapeDtypeStruct leaves (abstract banks) and uses a free expand_dims
    for singleton classes instead of a copying stack."""
    def stk(*ls):
        if isinstance(ls[0], jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct((len(ls),) + tuple(ls[0].shape),
                                        ls[0].dtype)
        if len(ls) == 1:
            return jnp.expand_dims(ls[0], 0)
        return jnp.stack(ls)
    return jax.tree.map(stk, *states)


def _class_member(state, ci: int):
    """Slice member group ``ci`` out of a class stack (static index)."""
    def sl(leaf):
        if isinstance(leaf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(tuple(leaf.shape)[1:], leaf.dtype)
        return leaf[ci]
    return jax.tree.map(sl, state)


class TileBank:
    """All analog tiles of a trainer, stored as class-keyed stacks.

    Canonical storage (checkpoint layout v4) is ``classes``: scan-class key
    -> TileState whose every array leaf carries TWO leading axes,
    ``(C, n, *member)`` — C member groups of n tiles each. Per-tile scalars
    (t, c, scale, prog) are (C, n) and per-tile seeds (C, n, 2). Storing the
    pre-stacked class directly is what lets the grouped engine's
    ``lax.scan`` consume state in place: zero ``jnp.stack`` on entry, zero
    ``leaf[ci]`` gather on exit, and the buffers donate straight through
    the step.

    ``index`` is the static path layout ((group, (member-path, ...)), ...)
    and ``class_index`` the static class layout ((class, (group, ...)), ...);
    both live in the pytree treedef (aux data) so they are hashable
    jit-static constants. ``groups`` remains available as a computed view
    (``leaf[ci]`` slices) for per-group consumers; the stack axes are
    element-local like everything else in a tile, which is what makes axis 1
    the natural ZeRO/scan sharding axis (DESIGN.md §3).

    ``policies`` optionally maps group key -> the TilePolicy every member of
    that stack resolved to under the trainer's AnalogPlan (policy is part of
    the class signature, so all groups of a class share one). Banks built
    without policies fall back to the trainer's default TileConfig.

    ``TileBank(groups, index, policies)`` — the per-group constructor —
    remains supported (legacy checkpoints, hand-assembled stacks, abstract
    skeletons) and eagerly re-keys into class storage;
    ``TileBank.from_classes`` is the zero-copy constructor the pytree
    unflattener and the trainer use.
    """

    def __init__(self, groups: Dict[str, "TileState"], index, policies=None):
        index = tuple((g, tuple(paths)) for g, paths in index)
        policies = dict(policies or {})
        class_index = class_partition(groups, index, policies)
        classes = {
            cname: _stack_states([groups[g] for g in gnames])
            for cname, gnames in class_index
        }
        self._init(classes, index, class_index, policies)

    @classmethod
    def from_classes(cls, classes: Dict[str, "TileState"], index,
                     class_index, policies=None) -> "TileBank":
        """Wrap existing class-keyed stacks without touching the leaves."""
        bank = cls.__new__(cls)
        bank._init(dict(classes), index, class_index, policies)
        return bank

    def _init(self, classes, index, class_index, policies):
        self.classes = dict(classes)
        self.index = tuple((g, tuple(paths)) for g, paths in index)
        self.class_index = tuple((c, tuple(gs)) for c, gs in class_index)
        self.policies = dict(policies or {})
        self._where = {p: (g, i) for g, paths in self.index
                       for i, p in enumerate(paths)}
        self._class_of = {g: (cname, ci)
                          for cname, gnames in self.class_index
                          for ci, g in enumerate(gnames)}
        self._groups_view = None

    def policy(self, group: str):
        """TilePolicy of one stack (None for policy-less legacy banks)."""
        return self.policies.get(group)

    @property
    def groups(self) -> Dict[str, "TileState"]:
        """Per-group view: {group: TileState with (n, *member) leaves},
        sliced out of the class stacks by static index (compat surface for
        per-group consumers; the engine itself reads ``classes``)."""
        if self._groups_view is None:
            self._groups_view = {
                g: _class_member(self.classes[cname], ci)
                for g, (cname, ci) in self._class_of.items()}
        return self._groups_view

    # -- mapping interface over member tiles --------------------------------
    def __len__(self) -> int:
        return len(self._where)

    def __contains__(self, path) -> bool:
        return (path in self._where or path in self._class_of
                or path in self.classes)

    def __iter__(self):
        return iter(self._where)

    def paths(self):
        return tuple(self._where)

    def __getitem__(self, path) -> "TileState":
        """Per-tile view, a per-group view, or a whole class stack."""
        if path in self.classes and path not in self._class_of:
            return self.classes[path]
        if path in self._class_of:
            return self.groups[path]
        g, i = self._where[path]
        cname, ci = self._class_of[g]
        return jax.tree.map(lambda leaf: leaf[ci, i], self.classes[cname])

    def __repr__(self):
        return (f"TileBank({len(self._where)} tiles in "
                f"{len(self._class_of)} groups / {len(self.classes)} "
                f"classes: {[c for c, _ in self.class_index]})")


def _tilebank_flatten(bank: TileBank):
    names = tuple(c for c, _ in bank.class_index)
    return (tuple((jax.tree_util.DictKey(c), bank.classes[c]) for c in names),
            (bank.index, bank.class_index,
             tuple(sorted(bank.policies.items()))))


jax.tree_util.register_pytree_with_keys(
    TileBank,
    _tilebank_flatten,
    lambda aux, classes: TileBank.from_classes(
        dict(zip((c for c, _ in aux[1]), classes)), aux[0], aux[1],
        dict(aux[2])),
)


def group_tiles(shapes: Dict[str, tuple], cfg: TileConfig, policies=None):
    """Static grouping: {path: weight shape} -> TileBank index layout.

    Groups key on (shape, state dtype, sharding-rule template, policy):

    * the rule template keeps same-shape tiles whose owning weights
      partition differently (attn/wq's (None, "M") vs attn/wo's
      ("M", None)) out of each other's stacks, so the stacked spec can
      always carry the model axis (``grouped_tile_spec``). The template is
      resolved mesh-independently from the PARAM_RULES table, so the
      grouping — and with it checkpoint group names — is identical on
      every mesh, including single-host.
    * the policy component (``policies``: {path: TilePolicy}) keeps tiles
      trained under different AnalogPlan policies apart — each stack has
      ONE static TileConfig, so the grouped engine mixes algorithms and
      device presets per group without giving up the O(distinct
      structures) program size. Single-policy plans omit the tag, keeping
      group keys byte-identical to the pre-AnalogPlan layout.
    """
    from repro.distributed.sharding import rule_template, template_tag

    multi = policies is not None and len(set(policies.values())) > 1
    if multi:
        by_tag: Dict[str, set] = {}
        for pol in policies.values():
            by_tag.setdefault(pol.tag, set()).add(pol)
        clashes = {t: ps for t, ps in by_tag.items() if len(ps) > 1}
        assert not clashes, (
            f"distinct TilePolicies share a tag (rename one): {clashes}")

    by_group: Dict[str, list] = {}
    for p in sorted(shapes):
        tag = template_tag(rule_template(p, len(shapes[p])))
        pol = (policies or {}).get(p)
        dtype = pol.tile.state_dtype if pol is not None else cfg.state_dtype
        ptag = pol.tag if (multi and pol is not None) else ""
        by_group.setdefault(
            group_name(shapes[p], dtype, tag, ptag), []).append(p)
    return tuple((g, tuple(by_group[g])) for g in sorted(by_group))


def group_policies(index, policies) -> Optional[Dict[str, Any]]:
    """{group: TilePolicy} for a grouping produced by ``group_tiles`` —
    every member of a group shares one policy by construction."""
    if not policies:
        return None
    return {g: policies[paths[0]] for g, paths in index}


def stack_tiles(per_tile: Dict[str, "TileState"], index, policies=None) -> TileBank:
    """Stack per-tile states along a new leading axis, per group."""
    groups = {}
    for g, paths in index:
        groups[g] = jax.tree.map(
            lambda *leaves: jnp.stack(leaves), *(per_tile[p] for p in paths))
    return TileBank(groups, index, policies)


def abstract_tile_group(shape, n: int, cfg: TileConfig) -> "TileState":
    """ShapeDtypeStruct skeleton of an ``n``-tile stacked group."""
    st = abstract_tile(shape, cfg)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), st)
