"""Pulse-update engine: the Analog Update (paper eq. 2/5) on device arrays.

Two fidelity modes:
  * ``fused`` (default): one aggregated update with a stochastically-rounded
    pulse count (exactly the b_k model of Assumption 3.4 — zero mean,
    Var = Theta(lr * dw_min); property-tested) + aggregated c2c noise.
    This is the TPU-native form (see DESIGN.md §3) and is served by the
    fused Pallas kernel / its jnp oracle.
  * ``train``: explicit BL-deep pulse train via lax.fori_loop, each pulse
    re-evaluating the response at the *current* weight (AIHWKit fidelity).
    Used by small-scale fidelity tests; O(BL)x more HBM traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref

from .device import DeviceConfig, DeviceParams, fg, responses


def analog_update(
    w,
    dw,
    dp: DeviceParams,
    cfg: DeviceConfig,
    key,
    *,
    bl: int = 0,
    mode: str = "fused",
    rng: str = "threefry",
    noise=None,
):
    """Apply desired increment ``dw`` to analog array ``w`` via pulses.

    ``noise`` optionally carries pre-drawn ``(ubits, zeta)`` (uint32 bits
    for the stochastic rounding + standard normal for c2c); the grouped
    engine's fused backend passes one batched stream for a whole stack.
    """
    if cfg.kind in ("softbounds", "linear") and mode == "fused":
        return kops.analog_update(
            w, dw, dp["gamma"], dp["rho"], key,
            dw_min=cfg.dw_min, tau_min=cfg.tau_min, tau_max=cfg.tau_max,
            sigma_c2c=cfg.sigma_c2c, bl=bl, rng=rng, noise=noise,
        )
    if mode == "fused":
        return _fused_generic(w, dw, dp, cfg, key, bl=bl, noise=noise)
    if mode == "train":
        return _pulse_train(w, dw, dp, cfg, key, bl=max(bl, 1))
    raise ValueError(f"unknown pulse mode {mode}")


def _stochastic_round(x, key):
    fl = jnp.floor(x)
    frac = x - fl
    u = jax.random.uniform(key, x.shape, jnp.float32)
    return fl + (u < frac).astype(jnp.float32)


def _fused_generic(w, dw, dp, cfg, key, *, bl, noise=None):
    """Fused update for any response family (jnp path; the kernels' oracle).

    With pre-drawn ``noise=(ubits, zeta)`` the rounding uniform is
    ``ubits * 2**-32`` — the exact expression the Pallas kernel and the
    jnp ref use — so this path is bit-comparable against them.
    """
    wf = w.astype(jnp.float32)
    if noise is None:
        ku, kz = jax.random.split(key)
        n_q = _stochastic_round(dw.astype(jnp.float32) / cfg.dw_min, ku)
        zeta = jax.random.normal(kz, w.shape)
    else:
        ubits, zeta = noise
        x = dw.astype(jnp.float32) / cfg.dw_min
        fl = jnp.floor(x)
        u = ubits.astype(jnp.float32) * (1.0 / 4294967296.0)
        n_q = fl + (u < x - fl).astype(jnp.float32)
    if bl:
        n_q = jnp.clip(n_q, -float(bl), float(bl))
    delta = n_q * cfg.dw_min
    f, g = fg(wf, dp, cfg)
    qp, qm = responses(wf, dp, cfg)
    q_dir = jnp.where(delta >= 0, qp, qm)
    amp = cfg.dw_min * cfg.sigma_c2c * jnp.sqrt(jnp.abs(n_q)) * q_dir
    out = wf + delta * f - jnp.abs(delta) * g + amp * zeta
    return jnp.clip(out, -cfg.tau_min, cfg.tau_max).astype(w.dtype)


def _pulse_train(w, dw, dp, cfg, key, *, bl):
    """Explicit sequential pulse train (response re-evaluated per pulse)."""
    ku, kz = jax.random.split(key)
    n_q = _stochastic_round(dw.astype(jnp.float32) / cfg.dw_min, ku)
    n_q = jnp.clip(n_q, -float(bl), float(bl))
    sign = jnp.sign(n_q)
    n_abs = jnp.abs(n_q)

    def body(i, carry):
        wf, k = carry
        k, kn = jax.random.split(k)
        live = (i < n_abs).astype(jnp.float32)
        eps = live * sign * cfg.dw_min
        qp, qm = responses(wf, dp, cfg)
        f = (qm + qp) * 0.5
        g = (qm - qp) * 0.5
        c2c = 1.0 + cfg.sigma_c2c * jax.random.normal(kn, wf.shape)
        step = (eps * f - jnp.abs(eps) * g) * c2c
        wf = jnp.clip(wf + step, -cfg.tau_min, cfg.tau_max)
        return wf, k

    wf, _ = jax.lax.fori_loop(0, bl, body, (w.astype(jnp.float32), kz))
    return wf.astype(w.dtype)


def zs_step(w, eps, dp: DeviceParams, cfg: DeviceConfig, key=None):
    """One zero-shifting pulse (paper eq. 7): w + eps*F(w) - |eps|*G(w).

    ``eps`` entries are +-dw_min. c2c noise applied when cfg.sigma_c2c > 0.
    """
    wf = w.astype(jnp.float32)
    f, g = fg(wf, dp, cfg)
    step = eps * f - jnp.abs(eps) * g
    if cfg.sigma_c2c > 0.0 and key is not None:
        step = step * (1.0 + cfg.sigma_c2c * jax.random.normal(key, wf.shape))
    return jnp.clip(wf + step, -cfg.tau_min, cfg.tau_max).astype(w.dtype)
