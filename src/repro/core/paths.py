"""Version-tolerant rendering of jax key paths as "a/b/c" strings.

jax >= 0.5 supports ``keystr(kp, simple=True, separator="/")``; jax 0.4.x
only accepts ``keystr(keys)``. Tree paths are the stable identifiers for
every leaf in this codebase (sharding rules, checkpoints, tile grouping),
so they must render identically across jax versions. ``npz_key`` /
``npz_path`` are the matching on-disk encoding used by checkpoint
manifests (np.savez member names cannot contain "/").
"""
from __future__ import annotations

import jax


def path_str(kp) -> str:
    try:
        return jax.tree_util.keystr(kp, simple=True, separator="/")
    except TypeError:
        parts = []
        for k in kp:
            if hasattr(k, "key"):        # DictKey / SequenceKey
                parts.append(str(k.key))
            elif hasattr(k, "name"):     # GetAttrKey
                parts.append(str(k.name))
            elif hasattr(k, "idx"):      # FlattenedIndexKey
                parts.append(str(k.idx))
            else:
                parts.append(str(k).strip("[].'\""))
        return "/".join(parts)


def npz_key(path: str) -> str:
    """Tree path -> npz member name ("tiles/g8x8_float32_nM/W" ->
    "tiles|g8x8_float32_nM|W"). Stable across releases: checkpoint
    manifests persist these names."""
    return path.replace("/", "|")


def npz_path(key: str) -> str:
    """Inverse of ``npz_key``."""
    return key.replace("|", "/")
