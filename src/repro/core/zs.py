"""Zero-shifting SP estimation (paper Algorithm 1) — stochastic and cyclic.

This is the *static* calibration baseline whose pulse complexity the paper
bounds (Thm 2.2: avg ||G||^2 <= O(1/(N dw_min)) + Theta(dw_min); Thm C.2:
last-iterate N <= log(.)/(2 mu_q dw_min) for monotone devices). The
benchmark ``benchmarks/fig1_zs.py`` sweeps N and dw_min against these rates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .device import DeviceConfig, DeviceParams, fg, symmetric_point
from .pulse import zs_step


def zs_estimate(
    key,
    w0,
    dp: DeviceParams,
    cfg: DeviceConfig,
    n_pulses: int,
    *,
    scheme: str = "stochastic",
    tail_average: Optional[bool] = None,
) -> jnp.ndarray:
    """Run Algorithm 1 for ``n_pulses`` pulses and return the SP estimate.

    scheme: 'stochastic' draws eps ~ U{-dw_min, +dw_min} i.i.d. per element;
            'cyclic' alternates +dw_min, -dw_min (paper eq. 31).

    tail_average: return the average of the last half of the iterates instead
    of W_N. Defaults to True for the stochastic scheme: Thm 2.2 bounds the
    *average* iterate, while the stochastic last iterate keeps a Theta(dw_min)
    jitter floor (each pulse moves a full +-dw_min step), so averaging the
    stationary tail recovers the theorem's rate. The cyclic scheme's +/- pairs
    cancel within one period, so its last iterate already sits on the floor
    (defaults to False).
    """
    if tail_average is None:
        tail_average = scheme == "stochastic"
    tail_start = n_pulses // 2 if tail_average else max(n_pulses - 1, 0)
    tail_len = max(n_pulses - tail_start, 1)

    def body(carry, n):
        w, acc, k = carry
        k, ke, kc = jax.random.split(k, 3)
        if scheme == "stochastic":
            sign = jnp.where(
                jax.random.bernoulli(ke, 0.5, w.shape), 1.0, -1.0
            )
        elif scheme == "cyclic":
            sign = jnp.where(n % 2 == 0, 1.0, -1.0) * jnp.ones_like(w)
        else:
            raise ValueError(scheme)
        eps = sign * cfg.dw_min
        w = zs_step(w, eps, dp, cfg, kc)
        acc = acc + jnp.where(n >= tail_start, w.astype(jnp.float32), 0.0)
        return (w, acc, k), None

    acc0 = jnp.zeros_like(w0, jnp.float32)
    (w, acc, _), _ = jax.lax.scan(body, (w0, acc0, key), jnp.arange(n_pulses))
    if n_pulses == 0:
        return w
    return (acc / tail_len).astype(w.dtype)


def zs_estimate_with_trace(
    key, w0, dp, cfg, n_pulses: int, *, scheme: str = "stochastic", every: int = 1
) -> Tuple[jnp.ndarray, dict]:
    """As zs_estimate but also returns traces of ||G(W_n)||^2 and SP error."""
    w_sp = symmetric_point(dp, cfg)

    def body(carry, n):
        w, k = carry
        k, ke, kc = jax.random.split(k, 3)
        if scheme == "stochastic":
            sign = jnp.where(jax.random.bernoulli(ke, 0.5, w.shape), 1.0, -1.0)
        else:
            sign = jnp.where(n % 2 == 0, 1.0, -1.0) * jnp.ones_like(w)
        w = zs_step(w, sign * cfg.dw_min, dp, cfg, kc)
        _, g = fg(w, dp, cfg)
        rec = (jnp.mean(g * g), jnp.mean((w - w_sp) ** 2))
        return (w, k), rec

    (w, _), (g_sq, err_sq) = jax.lax.scan(body, (w0, key), jnp.arange(n_pulses))
    return w, {"g_sq": g_sq, "sp_err_sq": err_sq}


def pulses_to_target(g_sq_trace, target: float) -> int:
    """Smallest N with running-average ||G||^2 <= target (-1 if never)."""
    import numpy as np

    g = np.asarray(g_sq_trace)
    avg = np.cumsum(g) / (np.arange(len(g)) + 1)
    hits = np.nonzero(avg <= target)[0]
    return int(hits[0]) + 1 if len(hits) else -1
