"""AnalogPlan: per-path policies for heterogeneous devices and algorithms.

The paper's SP behavior is *device-specific* (per-preset dw_min, asymmetry,
reference error), and the related work trains different layers on different
tile stacks (multi-tile residual learning; general non-ideal response
functions). A single global ``TileConfig`` + ``analog_filter`` predicate
cannot express any of that, so the user-facing training API is built around
two small immutable objects instead:

``TilePolicy``
    what one parameter gets: a full ``TileConfig`` (algorithm + device pair
    + hyper-parameters) or the ``DIGITAL`` sentinel (ordinary digital
    optimizer path).

``AnalogPlan``
    an *ordered* list of ``(pattern, policy)`` rules plus a default policy.
    Patterns are matched against the parameter's tree path in rule order —
    the FIRST match wins. Three pattern forms are accepted:

      * glob strings — ``"**/wq"``, ``"**/mlp/*"`` (``**`` crosses ``/``,
        ``*``/``?`` stay within one path segment, matched on the full path),
      * regex strings — ``"re:attn/(wq|wk)$"`` (``re.search`` semantics),
      * predicates — ``lambda path, leaf: ...`` (the legacy-shim form).

    Leaves with fewer than ``analog_min_ndim`` dims fall back to DIGITAL
    even when a rule matches (biases/norms stay digital, as in the paper's
    setups).

The plan is resolved once per trainer: every analog path gets its policy,
tiles group on (shape, state-dtype, sharding-rule template, **policy**), and
each group's vmapped/scanned update graph is built with its own policy's
``TileConfig`` — the grouped engine stays O(distinct structures) while
mixing algorithms and device presets freely per group.

The legacy ``AnalogTrainer(loss, cfg, analog_filter)`` constructor maps onto
a one-rule plan (``legacy_plan``) behind a one-time DeprecationWarning.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from .device import PRESETS, DeviceConfig
from .tile import TileConfig


@dataclasses.dataclass(frozen=True)
class TilePolicy:
    """One per-path analog policy: a TileConfig, or digital (tile=None).

    ``name`` is an optional stable label; a non-empty name becomes the
    policy tag used inside tile-group keys and checkpoint manifests (so
    name your policies when you care about checkpoint key stability across
    code versions). Unnamed policies hash their config into a 6-hex tag.
    """

    tile: Optional[TileConfig] = None
    name: str = ""

    @property
    def is_digital(self) -> bool:
        return self.tile is None

    @property
    def tag(self) -> str:
        """Short [a-z0-9]+ identifier used in group keys ("" for digital)."""
        if self.tile is None:
            return "digital"
        if self.name:
            t = re.sub(r"[^a-z0-9]", "", self.name.lower())
            if t:
                return t
        return hashlib.md5(repr(self.tile).encode()).hexdigest()[:6]

    @classmethod
    def of(cls, algorithm: str = "erider", device_p=None, device_w=None,
           *, name: str = "", **hyperparams) -> "TilePolicy":
        """Ergonomic constructor: devices may be DeviceConfigs or preset
        names from ``repro.core.device.PRESETS``; extra kwargs are
        TileConfig hyper-parameters (lr_p, eta, chopper_p, ...)."""
        if algorithm == "digital":
            return DIGITAL

        def dev(d):
            return PRESETS[d] if isinstance(d, str) else d

        device_p, device_w = dev(device_p), dev(device_w)
        if device_w is None:
            device_w = device_p if device_p is not None else PRESETS["reram_om"]
        if device_p is None:
            device_p = device_w
        return cls(
            TileConfig(algorithm=algorithm, device_p=device_p,
                       device_w=device_w, **hyperparams),
            name or algorithm,
        )

    def __repr__(self):
        if self.is_digital:
            return "TilePolicy(DIGITAL)"
        return (f"TilePolicy({self.name or self.tag}: {self.tile.algorithm}, "
                f"dw_min(p)={self.tile.device_p.dw_min})")


DIGITAL = TilePolicy(tile=None, name="digital")


def _glob_to_re(pattern: str) -> str:
    """Glob -> anchored regex. ``**/`` optionally crosses directories,
    ``**`` matches anything, ``*``/``?`` stay within one path segment."""
    out, i = [], 0
    while i < len(pattern):
        c = pattern[i]
        if pattern.startswith("**/", i):
            out.append(r"(?:.*/)?")
            i += 3
        elif pattern.startswith("**", i):
            out.append(r".*")
            i += 2
        elif c == "*":
            out.append(r"[^/]*")
            i += 1
        elif c == "?":
            out.append(r"[^/]")
            i += 1
        else:
            out.append(re.escape(c))
            i += 1
    return "".join(out)


def compile_pattern(pattern) -> Callable[[str, Any], bool]:
    """Pattern (glob / "re:" regex / predicate) -> (path, leaf) predicate."""
    if callable(pattern):
        return pattern
    if pattern.startswith("re:"):
        rx = re.compile(pattern[3:])
        return lambda path, leaf: rx.search(path) is not None
    rx = re.compile(_glob_to_re(pattern))
    return lambda path, leaf: rx.fullmatch(path) is not None


def _as_policy(p) -> TilePolicy:
    if isinstance(p, TilePolicy):
        return p
    if isinstance(p, TileConfig):
        return TilePolicy(tile=p)
    if p == "digital" or p is None:
        return DIGITAL
    raise TypeError(f"not a TilePolicy/TileConfig/'digital': {p!r}")


@dataclasses.dataclass(frozen=True)
class AnalogPlan:
    """Ordered (pattern, TilePolicy) rules + default; first match wins."""

    rules: Tuple[Tuple[Any, TilePolicy], ...] = ()
    default: TilePolicy = DIGITAL
    # rule-matched analog leaves below this rank stay digital anyway
    # (biases / norm vectors); 0 disables the guard (legacy-shim behavior).
    analog_min_ndim: int = 2

    def __post_init__(self):
        object.__setattr__(
            self, "_matchers",
            tuple((compile_pattern(pat), pol) for pat, pol in self.rules))

    @classmethod
    def of(cls, *rules, default=DIGITAL, analog_min_ndim: int = 2) -> "AnalogPlan":
        """``AnalogPlan.of(("**/wq", pol_a), ("**/mlp/*", pol_b))`` —
        policies may be TilePolicy, TileConfig, or the string "digital"."""
        return cls(
            rules=tuple((pat, _as_policy(pol)) for pat, pol in rules),
            default=_as_policy(default),
            analog_min_ndim=analog_min_ndim,
        )

    @classmethod
    def single(cls, policy, analog_filter=None, analog_min_ndim: int = 2) -> "AnalogPlan":
        """One policy everywhere (optionally gated by a predicate)."""
        pat = analog_filter if analog_filter is not None else "**"
        return cls.of((pat, policy), analog_min_ndim=analog_min_ndim)

    def policy_for(self, path: str, leaf=None) -> TilePolicy:
        """First matching rule's policy (the plan default otherwise); a
        too-low-rank leaf is digital regardless. ``leaf=None`` skips the
        rank guard (used on paths already known to be analog tiles)."""
        for match, pol in self._matchers:
            if match(path, leaf):
                found = pol
                break
        else:
            found = self.default
        if (not found.is_digital and leaf is not None
                and getattr(leaf, "ndim", 0) < self.analog_min_ndim):
            return DIGITAL
        return found

    def policies(self) -> Tuple[TilePolicy, ...]:
        out = []
        for _, pol in self.rules:
            if pol not in out:
                out.append(pol)
        if self.default not in out:
            out.append(self.default)
        return tuple(out)

    def __repr__(self):
        pats = [pat if isinstance(pat, str) else "<predicate>"
                for pat, _ in self.rules]
        return f"AnalogPlan({len(self.rules)} rules: {pats}, default={self.default.name})"


def plan_partition(params, plan: AnalogPlan):
    """Split a param tree by plan: (digital tree with None at analog slots,
    {path: leaf} analog dict, {path: TilePolicy} resolved policies)."""
    import jax

    from .paths import path_str

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    analog: Dict[str, Any] = {}
    policies: Dict[str, TilePolicy] = {}
    dig_leaves = []
    for kp, leaf in flat:
        p = path_str(kp)
        pol = plan.policy_for(p, leaf)
        if pol.is_digital:
            dig_leaves.append(leaf)
        else:
            analog[p] = leaf
            policies[p] = pol
            dig_leaves.append(None)
    return jax.tree_util.tree_unflatten(treedef, dig_leaves), analog, policies


# ---------------------------------------------------------------------------
# checkpoint serialization of resolved policies (manifest layout v3)
# ---------------------------------------------------------------------------


def policy_to_json(pol: TilePolicy) -> dict:
    if pol.is_digital:
        return {"name": pol.name or "digital", "digital": True}
    d = dataclasses.asdict(pol.tile)
    d["state_dtype"] = jnp.dtype(pol.tile.state_dtype).name
    return {"name": pol.name, "tag": pol.tag, "tile": d}


def policy_from_json(d: dict) -> TilePolicy:
    if d.get("digital"):
        return DIGITAL
    t = dict(d["tile"])
    t["device_p"] = DeviceConfig(**t["device_p"])
    t["device_w"] = DeviceConfig(**t["device_w"])
    t["state_dtype"] = jnp.dtype(t["state_dtype"]).type
    return TilePolicy(tile=TileConfig(**t), name=d.get("name", ""))


# ---------------------------------------------------------------------------
# legacy (TileConfig, analog_filter) shim
# ---------------------------------------------------------------------------

_LEGACY_WARNED = False


def _reset_legacy_warning() -> None:
    """Test hook: re-arm the one-time deprecation warning."""
    global _LEGACY_WARNED
    _LEGACY_WARNED = False


def legacy_plan(tile: TileConfig, analog_filter) -> AnalogPlan:
    """Map the deprecated ``(cfg.tile, analog_filter)`` pair onto a one-rule
    plan, warning once per process."""
    global _LEGACY_WARNED
    if not _LEGACY_WARNED:
        _LEGACY_WARNED = True
        warnings.warn(
            "AnalogTrainer(cfg, analog_filter=...) with a single global "
            "TileConfig is deprecated; pass plan=repro.api.AnalogPlan.of("
            "(pattern, TilePolicy), ...) instead",
            DeprecationWarning, stacklevel=3)
    # min_ndim 0: the legacy predicate alone decided what was analog
    return AnalogPlan.of((analog_filter, TilePolicy(tile=tile)),
                         analog_min_ndim=0)
