"""Analog resistive-device models (paper §4 "Device model" + Appendix F.1).

A *device* here is the per-cross-point physics of one analog tile: the pair
of response functions (q+, q-) that scale every up/down conductance pulse.
We implement the SoftBoundsReference family used by the paper (IBM AIHWKit
presets, Table 3) plus the broader training-friendly families of Def. 2.1 /
C.1 (linear-monotone, exponential) used by the theory tests.

Per-element device-to-device (d2d) sampling follows App. F.1:
    gamma_ij = exp(sigma_d2d * xi)      (common slope, lognormal)
    rho_ij   = sigma_pm * xi'           (up/down asymmetry, normal)
    alpha+ = gamma + rho,  alpha- = gamma - rho

Ground-truth symmetric point (G(w)=0), with the sign typo of paper eq. (110)
corrected (see DESIGN.md §1):
    w_sp = (alpha+ - alpha-) / (alpha+/tau_max + alpha-/tau_min)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """Static (non-pytree) description of a device family/preset."""

    kind: str = "softbounds"      # softbounds | linear | exp
    tau_min: float = 1.0          # lower bound is -tau_min (tau_min > 0)
    tau_max: float = 1.0
    dw_min: float = 0.001         # response granularity
    sigma_d2d: float = 0.0        # d2d slope variation (lognormal sigma)
    sigma_pm: float = 0.0         # d2d asymmetry variation
    sigma_c2c: float = 0.0        # cycle-to-cycle write noise
    # Optional nonzero-SP initialization for robustness studies (Tables 1-2):
    # rho is shifted so the per-element SP ~ N(ref_mean, ref_std^2).
    ref_mean: float = 0.0
    ref_std: float = 0.0
    # exp-family curvature (only for kind == "exp")
    exp_kappa: float = 0.5
    # --- lifetime (post-training) physics, consumed by repro.lifetime ---
    # Conductance drift W(t) = W(t0) * (t/t0)^-nu (Rasch et al. HWA
    # replications): nu is sampled per element ~ N(drift_nu, drift_nu_std^2),
    # clipped to >= 0; drift_t0 is the reference instant (seconds after
    # programming) the checkpointed state is defined at. All defaults are
    # no-op values so pre-lifetime checkpoints and presets behave
    # identically (the stored-keys-only policy compare relies on this).
    drift_nu: float = 0.0
    drift_nu_std: float = 0.0
    drift_t0: float = 1.0
    # Write-and-verify programming error: one write lands at
    # w + N(0, sigma_p(w)^2) with the state-dependent
    # sigma_p(w) = prog_noise + prog_noise_slope * |w|; each verify round
    # reads back (read_noise-corrupted) and applies a corrective write
    # whose own error is proportional to the correction magnitude.
    prog_noise: float = 0.0
    prog_noise_slope: float = 0.0
    prog_rounds: int = 1
    # Additive conductance read noise (weight units) on any post-t0 read.
    read_noise: float = 0.0

    @property
    def num_states(self) -> float:
        """Number of conductance states across the dynamic range."""
        return (self.tau_max + self.tau_min) / self.dw_min


# AIHWKit-style presets from paper Table 3, with per-preset lifetime
# coefficients (drift exponent, programming/read noise) in the units of the
# normalized weight range. ReRAM drift is weak relative to PCM (retention
# loss dominated by filament relaxation); the PCM preset carries the
# canonical nu ~ 0.06 of d-GST mushroom cells.
PRESETS = {
    # HfO2-based ReRAM (Gong et al., 2022b): very few states (~4-5)
    "reram_hfo2": DeviceConfig(
        kind="softbounds", tau_min=1.0, tau_max=1.0, dw_min=0.4622,
        sigma_d2d=0.1, sigma_pm=0.7125, sigma_c2c=0.2174,
        drift_nu=0.01, drift_nu_std=0.004, prog_noise=0.02,
        prog_noise_slope=0.05, read_noise=0.01,
    ),
    # ReRamArrayOMPresetDevice (Gong et al., 2022b)
    "reram_om": DeviceConfig(
        kind="softbounds", tau_min=1.0, tau_max=1.0, dw_min=0.0949,
        sigma_d2d=0.1, sigma_pm=0.7829, sigma_c2c=0.4158,
        drift_nu=0.01, drift_nu_std=0.004, prog_noise=0.01,
        prog_noise_slope=0.04, read_noise=0.005,
    ),
    # High-precision device used for the ZS complexity study (Fig. 1)
    "softbounds_2000": DeviceConfig(
        kind="softbounds", tau_min=1.0, tau_max=1.0, dw_min=0.001,
        sigma_d2d=0.1, sigma_pm=0.3, sigma_c2c=0.05,
        drift_nu=0.005, drift_nu_std=0.002, prog_noise=0.002,
        prog_noise_slope=0.01, read_noise=0.002,
    ),
    # ECRAM-style preset (AIHWKit EcRamPresetDevice analogue): ~1000 states,
    # milder asymmetry than the ReRAM presets but nonzero write noise —
    # the "good device" partner in mixed-device plans.
    "ecram": DeviceConfig(
        kind="softbounds", tau_min=1.0, tau_max=1.0, dw_min=0.002,
        sigma_d2d=0.1, sigma_pm=0.25, sigma_c2c=0.15,
        drift_nu=0.002, drift_nu_std=0.001, prog_noise=0.004,
        prog_noise_slope=0.02, read_noise=0.002,
    ),
    # Mushroom-cell d-GST PCM (Rasch et al. HWA replications, SNIPPETS.md
    # snippets 1 and 3): the canonical drifting device GDC was built for —
    # nu ~ 0.06 with wide d2d spread, strongly state-dependent programming
    # error, t0 ~ 20 s after program-and-verify.
    "pcm_gst": DeviceConfig(
        kind="softbounds", tau_min=1.0, tau_max=1.0, dw_min=0.005,
        sigma_d2d=0.1, sigma_pm=0.3, sigma_c2c=0.05,
        drift_nu=0.06, drift_nu_std=0.02, drift_t0=20.0,
        prog_noise=0.01, prog_noise_slope=0.07, prog_rounds=3,
        read_noise=0.005,
    ),
    # Idealized symmetric device (digital-like reference)
    "ideal": DeviceConfig(
        kind="softbounds", tau_min=10.0, tau_max=10.0, dw_min=1e-6,
        sigma_d2d=0.0, sigma_pm=0.0, sigma_c2c=0.0,
    ),
}


class DeviceParams(dict):
    """Pytree of per-element device parameters ({'gamma','rho'} arrays)."""


jax.tree_util.register_pytree_with_keys(
    DeviceParams,
    lambda d: (tuple((jax.tree_util.DictKey(k), d[k]) for k in sorted(d)),
               tuple(sorted(d))),
    lambda keys, vals: DeviceParams(zip(keys, vals)),
)


def sample_device(key, shape, cfg: DeviceConfig, method: str = "threefry") -> DeviceParams:
    """Sample per-element (gamma, rho) for a tile of `shape` (App. F.1).

    method='hash' draws from the fused stateless hash RNG (sharding-friendly
    regeneration path at LM scale; see kernels/fastrng.py).
    """
    if method == "hash":
        from repro.kernels import fastrng

        seed = fastrng.seed_from_key(key)
        n_g = fastrng.hash_normal(seed, shape, 11)
        n_r = fastrng.hash_normal(seed, shape, 13)
        n_s = fastrng.hash_normal(seed, shape, 17)
    else:
        kg, kr, ks = jax.random.split(key, 3)
        n_g = jax.random.normal(kg, shape, jnp.float32)
        n_r = jax.random.normal(kr, shape, jnp.float32)
        n_s = jax.random.normal(ks, shape, jnp.float32)
    if cfg.sigma_d2d > 0:
        gamma = jnp.exp(cfg.sigma_d2d * n_g)
    else:
        gamma = jnp.ones(shape, jnp.float32)
    # Def. 2.1 positive-definiteness: |rho| < gamma keeps both alpha+- > 0
    rho = jnp.clip(cfg.sigma_pm * n_r, -0.95 * gamma, 0.95 * gamma)

    if cfg.ref_mean != 0.0 or cfg.ref_std != 0.0:
        # Solve for rho that realizes a target SP w* ~ N(ref_mean, ref_std^2):
        #   w* = 2 rho / ((gamma+rho)/tmax + (gamma-rho)/tmin)
        # => rho = w* gamma (tmin + tmax) / (2 tmin tmax + w*(tmin - tmax))
        w_star = cfg.ref_mean + cfg.ref_std * n_s
        w_star = jnp.clip(w_star, -0.95 * cfg.tau_min, 0.95 * cfg.tau_max)
        num = w_star * gamma * (cfg.tau_min + cfg.tau_max)
        den = 2.0 * cfg.tau_min * cfg.tau_max + w_star * (cfg.tau_min - cfg.tau_max)
        rho = num / den
        # keep alpha+- positive (Def. 2.1 positive-definiteness)
        rho = jnp.clip(rho, -0.95 * gamma, 0.95 * gamma)
    return DeviceParams(gamma=gamma, rho=rho)


def abstract_device(shape, dtype=jnp.float32) -> DeviceParams:
    """ShapeDtypeStruct stand-in (for dry-run lowering)."""
    s = jax.ShapeDtypeStruct(shape, dtype)
    return DeviceParams(gamma=s, rho=s)


# ---------------------------------------------------------------------------
# Response functions
# ---------------------------------------------------------------------------


def responses(w, dp: DeviceParams, cfg: DeviceConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(q_plus, q_minus) for the device family."""
    gamma, rho = dp["gamma"], dp["rho"]
    if cfg.kind in ("softbounds", "linear"):
        qp = kref.q_plus(w, gamma, rho, cfg.tau_max)
        qm = kref.q_minus(w, gamma, rho, cfg.tau_min)
    elif cfg.kind == "exp":
        # monotone exponential family (Def. C.1): q+ decreasing, q- increasing
        qp = (gamma + rho) * jnp.exp(-cfg.exp_kappa * w / cfg.tau_max)
        qm = (gamma - rho) * jnp.exp(cfg.exp_kappa * w / cfg.tau_min)
    else:
        raise ValueError(f"unknown device kind {cfg.kind}")
    # Def 2.1 positive-definiteness: clip away dead regions
    eps = 1e-4
    return jnp.maximum(qp, eps), jnp.maximum(qm, eps)


def fg(w, dp: DeviceParams, cfg: DeviceConfig):
    qp, qm = responses(w, dp, cfg)
    return (qm + qp) * 0.5, (qm - qp) * 0.5


def symmetric_point(dp: DeviceParams, cfg: DeviceConfig):
    """Ground-truth SP (G(w)=0). Closed form for softbounds; exp family has
    w_sp where (gamma-rho) e^{k w/tmin} = (gamma+rho) e^{-k w/tmax}."""
    gamma, rho = dp["gamma"], dp["rho"]
    a_p = gamma + rho
    a_m = gamma - rho
    if cfg.kind in ("softbounds", "linear"):
        return (a_p - a_m) / (a_p / cfg.tau_max + a_m / cfg.tau_min)
    if cfg.kind == "exp":
        k = cfg.exp_kappa
        return jnp.log(a_p / a_m) / (k / cfg.tau_min + k / cfg.tau_max)
    raise ValueError(cfg.kind)
