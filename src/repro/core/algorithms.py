"""The seven analog training algorithms over a unified tile interface.

Every algorithm implements three pure functions:

  begin_step(state, key, cfg)        -> state'        (pre-forward phase:
                                                        chopper draw + E-RIDER
                                                        Q-tilde sync, Alg.3 l.3-6)
  effective_weight(state, cfg)       -> model weight   (what fwd/bwd sees)
  update(state, grad, key, cfg, lr)  -> (state', metrics)

``grad`` is the gradient w.r.t. the *model* weight returned by
``effective_weight`` — i.e. exactly the paper's ∇f(W̄_k; ξ_k) chain.

Algorithms (paper refs):
  sgd       — plain Analog SGD (eq. 2); exhibits the SP drift of eq. (4).
  ttv1      — Tiki-Taka v1 (Gokmen & Haensch 2020): fast array P + main W,
              periodic analog transfer, fwd on W + γP.
  ttv2      — Tiki-Taka v2 (Gokmen 2021): + digital hidden accumulator H with
              thresholded transfer (forget-buffer).
  agad      — AGAD (Rasch et al. 2024): chopped TT-v2; gradients evaluated at
              the *main* array only (paper App. B.2).
  residual  — two-stage Residual Learning + ZS (paper Alg. 4; Wu et al. 2025):
              Q ≡ static SP estimate.
  rider     — RIDER (paper Alg. 2): eq. (11a), (12), (11b).
  erider    — E-RIDER (paper Alg. 3): chopper (17), updates (18a/18b),
              periodic Q̃ programming on chopper flips.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .device import fg, symmetric_point
from .pulse import analog_update
from .tile import TileConfig, TileState, expected_pulses

Metrics = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _au(x, dx, dev, dcfg, key, cfg: TileConfig):
    return analog_update(x, dx, dev, dcfg, key, bl=cfg.bl, mode=cfg.pulse_mode,
                         rng=cfg.rng)


def _dev(st: TileState, which: str, cfg: TileConfig, shape):
    """Fetch device params; regenerate from the tile seed when not stored
    (store_device=False — DESIGN.md §3 memory/compute trade)."""
    dev = st.get(f"dev_{which}")
    if dev is not None:
        return dev
    from .device import sample_device

    key = jax.random.wrap_key_data(st[f"seed_{which}"])
    dcfg = cfg.device_p if which == "p" else cfg.device_w
    return sample_device(key, shape, dcfg, method=cfg.rng)


def _base_metrics(cfg: TileConfig, st: TileState, dw_p=None, dw_w=None) -> Metrics:
    # each diagnostic below is an extra full pass + reduction over the tile;
    # cfg.metrics trades them away at LM scale ('pulses' / 'none')
    if cfg.metrics == "none":
        return {}
    m: Metrics = {}
    pulses = jnp.zeros((), jnp.float32)
    if dw_p is not None:
        pulses = pulses + expected_pulses(dw_p, cfg.device_p.dw_min, cfg.bl)
    if dw_w is not None:
        pulses = pulses + expected_pulses(dw_w, cfg.device_w.dw_min, cfg.bl)
    m["pulses"] = pulses
    has_dev_p = st.get("dev_p") is not None or st.get("seed_p") is not None
    if cfg.metrics == "pulses":
        return m
    if st.get("P") is not None and has_dev_p:
        dev_p = _dev(st, "p", cfg, st["P"].shape)
        _, g = fg(st["P"].astype(jnp.float32), dev_p, cfg.device_p)
        m["gp_sq"] = jnp.mean(g * g)
        if st.get("Qd") is not None:
            sp = symmetric_point(dev_p, cfg.device_p)
            m["sp_err"] = jnp.mean((st["Qd"].astype(jnp.float32) - sp) ** 2)
    return m


def _grad_to_analog(st: TileState, grad, cfg: TileConfig):
    """Model-space gradient -> analog-space gradient (chain through scale).

    With grad_norm='absmean' the gradient is rescaled so a fast-LR of 1.0
    delivers ~1 pulse per element per step regardless of device granularity
    (the AIHWKit auto-granularity mechanism the paper's configs rely on).

    The mean-|g| here must be *per tile*: the batched tile engine drives this
    through jax.vmap over the TileBank stack axis, so `jnp.mean` sees one
    tile's slice, never the whole stack. Callers operating on stacked arrays
    directly must vmap — a raw call would normalize across the group and
    couple tiles of different gradient magnitude.
    """
    g = grad.astype(jnp.float32) * st["scale"]
    if cfg.grad_norm == "absmean":
        g = g / (jnp.mean(jnp.abs(g)) + 1e-12) * cfg.device_p.dw_min
    return g


# ---------------------------------------------------------------------------
# begin_step
# ---------------------------------------------------------------------------


def begin_step(st: TileState, key, cfg: TileConfig) -> TileState:
    """Pre-forward phase: draw chopper c_k (17); E-RIDER syncs Q̃ on flips."""
    if cfg.algorithm not in ("agad", "erider"):
        return st
    st = TileState(st)
    flip = jax.random.bernoulli(key, cfg.chopper_p)
    c_new = jnp.where(flip, -st["c"], st["c"])
    st["c"] = c_new
    if cfg.algorithm == "erider":
        # Alg. 3 lines 4-6: on sign change, reprogram the analog Q̃ from the
        # digital Q (weight programming event).
        st["Qt"] = jnp.where(flip, st["Qd"], st["Qt"])
        st["prog"] = st["prog"] + flip.astype(jnp.int32)
    return st


# ---------------------------------------------------------------------------
# effective weight (model space)
# ---------------------------------------------------------------------------


def effective_weight(st: TileState, cfg: TileConfig):
    a = cfg.algorithm
    w = st["W"].astype(jnp.float32)
    if a == "sgd":
        eff = w
    elif a in ("ttv1", "ttv2"):
        eff = w + cfg.gamma * st["P"].astype(jnp.float32)
    elif a == "agad":
        eff = w  # gradients on the main array only (App. B.2)
    elif a == "residual":
        eff = w + cfg.gamma * (st["P"] - st["Qd"]).astype(jnp.float32)
    elif a == "rider":
        eff = w + cfg.gamma * (st["P"] - st["Qd"]).astype(jnp.float32)
    elif a == "erider":
        eff = w + cfg.gamma * st["c"] * (st["P"] - st["Qt"]).astype(jnp.float32)
    else:
        raise ValueError(a)
    # model-space weight in the tile's storage dtype (bf16 at LM scale)
    return (eff * st["scale"]).astype(st["W"].dtype)


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def update(
    st: TileState, grad, key, cfg: TileConfig, lr
) -> Tuple[TileState, Metrics]:
    a = cfg.algorithm
    st = TileState(st)
    g = _grad_to_analog(st, grad, cfg)
    kp, kw, kq = jax.random.split(key, 3)
    alpha = lr * cfg.lr_p
    beta = lr * cfg.lr_w
    dev_w = _dev(st, "w", cfg, st["W"].shape)
    dev_p = _dev(st, "p", cfg, st["W"].shape) if (
        st.get("dev_p") is not None or st.get("seed_p") is not None) else None

    if a == "sgd":
        dw = -beta * g
        st["W"] = _au(st["W"], dw, dev_w, cfg.device_w, kw, cfg)
        metrics = _base_metrics(cfg, st, dw_w=dw)

    elif a in ("ttv1", "ttv2", "agad"):
        c = st["c"] if a == "agad" else jnp.ones((), jnp.float32)
        dp = -alpha * c * g
        st["P"] = _au(st["P"], dp, dev_p, cfg.device_p, kp, cfg)
        do_transfer = (st["t"] % cfg.transfer_every) == 0
        read = st["P"].astype(jnp.float32)  # analog readout of the fast array
        if a == "ttv1":
            dw = jnp.where(do_transfer, beta * read, 0.0)
            st["W"] = _au(st["W"], dw, dev_w, cfg.device_w, kw, cfg)
        else:
            if a == "agad":
                # Dynamic reference estimation (Rasch et al. 2024): an
                # un-demodulated low-pass of the readout isolates the DC
                # component = the fast array's drift point; transfers are
                # demodulated *and* offset-corrected.
                st["Qd"] = ((1.0 - cfg.eta) * st["Qd"].astype(jnp.float32)
                            + cfg.eta * read).astype(st["Qd"].dtype)
                read = read - st["Qd"].astype(jnp.float32)
            # TT-v2 / AGAD: digital hidden accumulator with thresholded
            # transfer and forget-buffer semantics.
            thr = cfg.threshold * cfg.device_w.dw_min
            h = st["H"] + jnp.where(do_transfer, beta * c * read, 0.0)
            n = jnp.trunc(h / thr)
            dw = n * thr
            st["H"] = h - dw
            st["W"] = _au(st["W"], dw, dev_w, cfg.device_w, kw, cfg)
        metrics = _base_metrics(cfg, st, dw_p=dp, dw_w=dw)

    elif a in ("residual", "rider", "erider"):
        c = st["c"] if a == "erider" else jnp.ones((), jnp.float32)
        # (11a)/(18a): P <- P - alpha c grad  (asymmetric pulse update)
        dp = -alpha * c * g
        st["P"] = _au(st["P"], dp, dev_p, cfg.device_p, kp, cfg)
        p_new = st["P"].astype(jnp.float32)
        # (11b)/(18b): W <- W + beta c (P_{k+1} - Q_k)
        q_ref = st["Qt"] if a == "erider" else st["Qd"]
        dw = beta * c * (p_new - q_ref.astype(jnp.float32))
        if cfg.buffered_transfer:
            # digital forget-buffer: emit only whole-pulse increments
            thr = cfg.threshold * cfg.device_w.dw_min
            h = st["H"] + dw
            dw = jnp.trunc(h / thr) * thr
            st["H"] = h - dw
        st["W"] = _au(st["W"], dw, dev_w, cfg.device_w, kw, cfg)
        # (12): digital EMA tracking (rider/erider only)
        if a in ("rider", "erider"):
            st["Qd"] = ((1.0 - cfg.eta) * st["Qd"].astype(jnp.float32)
                        + cfg.eta * p_new).astype(st["Qd"].dtype)
        metrics = _base_metrics(cfg, st, dw_p=dp, dw_w=dw)
        if a == "erider" and cfg.metrics != "none":
            metrics["prog_events"] = st["prog"].astype(jnp.float32)

    else:
        raise ValueError(a)

    st["t"] = st["t"] + 1
    return st, metrics


# ---------------------------------------------------------------------------
# batched update (the grouped engine's 'fused' backend)
# ---------------------------------------------------------------------------


def _hash_noise_batched(seeds, shape):
    """Per-tile fastrng streams for a (n, *shape) stack: row i consumes
    exactly the bits ``kops.analog_update(rng='hash')`` would draw for tile
    i alone (seed = raw key data, salts 1/2), so the batched update stays
    bit-identical to the vmapped per-tile one."""
    from repro.kernels import fastrng

    ub = jax.vmap(lambda s: fastrng.hash_bits(s, shape, 1))(seeds)
    zt = jax.vmap(lambda s: fastrng.hash_normal(s, shape, 2))(seeds)
    return ub, zt


def update_batched(
    st: TileState, grad, keys_raw, cfg: TileConfig, lr
) -> Tuple[TileState, Metrics]:
    """``update`` over a whole (n, *member) group stack in one program.

    ``st`` is a TileBank group stack (array leaves (n, *member), per-tile
    scalars (n,), seeds (n, 2)); ``keys_raw`` is the (n, 2) raw key data the
    vmap backend would hand each tile. Noise comes from per-tile fastrng
    hash streams (no threefry while-loops over weight-sized arrays) and the
    pulse update runs on the full stack — on TPU that is one 3-D batched
    Pallas kernel launch per array. Bit-identical to
    ``jax.vmap(update)(..., rng='hash')`` (tested): same per-tile key
    derivation, same hash bits, same elementwise math — only the program
    shape differs. Per-tile reductions (absmean grad norm, metrics) reduce
    over member axes only, so tiles never couple.
    """
    from .device import sample_device

    a = cfg.algorithm
    st = TileState(st)
    nd = st["W"].ndim
    axes = tuple(range(1, nd))
    member = st["W"].shape[1:]

    def bc(x):  # per-tile scalar (n,) -> broadcast shape (n, 1, ..., 1)
        return x.reshape(x.shape + (1,) * (nd - x.ndim))

    def dev_of(which):
        dev = st.get(f"dev_{which}")
        if dev is not None:
            return dev
        dcfg = cfg.device_p if which == "p" else cfg.device_w
        return jax.vmap(lambda sd: sample_device(
            jax.random.wrap_key_data(sd), member, dcfg, method="hash")
        )(st[f"seed_{which}"])

    def au(x, dx, dev, dcfg, kraw):
        noise = _hash_noise_batched(kraw, member)
        return analog_update(x, dx, dev, dcfg, None, bl=cfg.bl,
                             mode=cfg.pulse_mode, noise=noise)

    def pulses_of(dw, dw_min):
        n = jnp.abs(dw.astype(jnp.float32)) / dw_min
        if cfg.bl:
            n = jnp.minimum(n, float(cfg.bl))
        return jnp.sum(n, axis=axes)

    def base_metrics(dw_p=None, dw_w=None) -> Metrics:
        if cfg.metrics == "none":
            return {}
        m: Metrics = {}
        pulses = jnp.zeros(st["scale"].shape, jnp.float32)
        if dw_p is not None:
            pulses = pulses + pulses_of(dw_p, cfg.device_p.dw_min)
        if dw_w is not None:
            pulses = pulses + pulses_of(dw_w, cfg.device_w.dw_min)
        m["pulses"] = pulses
        has_dev_p = st.get("dev_p") is not None or st.get("seed_p") is not None
        if cfg.metrics == "pulses":
            return m
        if st.get("P") is not None and has_dev_p:
            dev_p = dev_of("p")
            _, gg = fg(st["P"].astype(jnp.float32), dev_p, cfg.device_p)
            m["gp_sq"] = jnp.mean(gg * gg, axis=axes)
            if st.get("Qd") is not None:
                sp = symmetric_point(dev_p, cfg.device_p)
                m["sp_err"] = jnp.mean(
                    (st["Qd"].astype(jnp.float32) - sp) ** 2, axis=axes)
        return m

    g = grad.astype(jnp.float32) * bc(st["scale"])
    if cfg.grad_norm == "absmean":
        g = (g / (jnp.mean(jnp.abs(g), axis=axes, keepdims=True) + 1e-12)
             * cfg.device_p.dw_min)
    # per-tile kp/kw key chain, identical to update()'s split(key, 3)
    ks = jax.vmap(lambda kr: jax.random.key_data(
        jax.random.split(jax.random.wrap_key_data(kr), 3)))(keys_raw)
    kp, kw = ks[:, 0], ks[:, 1]
    alpha = lr * cfg.lr_p
    beta = lr * cfg.lr_w
    dev_w = dev_of("w")
    dev_p = dev_of("p") if (st.get("dev_p") is not None
                            or st.get("seed_p") is not None) else None

    if a == "sgd":
        dw = -beta * g
        st["W"] = au(st["W"], dw, dev_w, cfg.device_w, kw)
        metrics = base_metrics(dw_w=dw)

    elif a in ("ttv1", "ttv2", "agad"):
        c = bc(st["c"]) if a == "agad" else jnp.ones((), jnp.float32)
        dp = -alpha * c * g
        st["P"] = au(st["P"], dp, dev_p, cfg.device_p, kp)
        do_transfer = bc((st["t"] % cfg.transfer_every) == 0)
        read = st["P"].astype(jnp.float32)
        if a == "ttv1":
            dw = jnp.where(do_transfer, beta * read, 0.0)
            st["W"] = au(st["W"], dw, dev_w, cfg.device_w, kw)
        else:
            if a == "agad":
                st["Qd"] = ((1.0 - cfg.eta) * st["Qd"].astype(jnp.float32)
                            + cfg.eta * read).astype(st["Qd"].dtype)
                read = read - st["Qd"].astype(jnp.float32)
            thr = cfg.threshold * cfg.device_w.dw_min
            h = st["H"] + jnp.where(do_transfer, beta * c * read, 0.0)
            n = jnp.trunc(h / thr)
            dw = n * thr
            st["H"] = h - dw
            st["W"] = au(st["W"], dw, dev_w, cfg.device_w, kw)
        metrics = base_metrics(dw_p=dp, dw_w=dw)

    elif a in ("residual", "rider", "erider"):
        c = bc(st["c"]) if a == "erider" else jnp.ones((), jnp.float32)
        dp = -alpha * c * g
        st["P"] = au(st["P"], dp, dev_p, cfg.device_p, kp)
        p_new = st["P"].astype(jnp.float32)
        q_ref = st["Qt"] if a == "erider" else st["Qd"]
        dw = beta * c * (p_new - q_ref.astype(jnp.float32))
        if cfg.buffered_transfer:
            thr = cfg.threshold * cfg.device_w.dw_min
            h = st["H"] + dw
            dw = jnp.trunc(h / thr) * thr
            st["H"] = h - dw
        st["W"] = au(st["W"], dw, dev_w, cfg.device_w, kw)
        if a in ("rider", "erider"):
            st["Qd"] = ((1.0 - cfg.eta) * st["Qd"].astype(jnp.float32)
                        + cfg.eta * p_new).astype(st["Qd"].dtype)
        metrics = base_metrics(dw_p=dp, dw_w=dw)
        if a == "erider" and cfg.metrics != "none":
            metrics["prog_events"] = st["prog"].astype(jnp.float32)

    else:
        raise ValueError(a)

    st["t"] = st["t"] + 1
    return st, metrics
