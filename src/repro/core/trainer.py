"""AnalogTrainer: wires any JAX model to the analog tile algorithms.

Given a loss function over a parameter pytree and an ``AnalogPlan``
(ordered path rules -> TilePolicy; see core/plan.py) deciding which leaves
live on which analog tile stacks, builds pure jit-able ``init`` /
``train_step`` functions:

  1. ``begin_step`` phase (chopper draw / Q-tilde sync, Alg.3 l.3-6)
  2. forward/backward on the *effective* parameter tree
     (analog leaves -> scale * W̄, paper's mixed weight)
  3. digital leaves -> SGD/Adam; analog leaves -> pulse-based tile update

Tiles are stored shape-grouped (TileBank): all tiles of one (shape, dtype,
sharding-rule template, policy) stack along a leading axis and phases 1/3b
run as ONE vmapped instance per group — each group's graph built with its
own policy's static TileConfig, so one train_step mixes algorithms and
device presets freely; groups with identical stacked structure AND policy
(same member shape/count/dtype, e.g. the wq-family and wo-family of a
uniform transformer) additionally share one ``jax.lax.scan``'ed graph, so
the jitted train_step stays O(distinct structures) — O(1) in depth — not
O(layers). ``TrainerConfig(engine="looped")`` keeps the legacy per-tile
dict layout and Python loop as a reference baseline;
``TrainerConfig(scan_groups=False)`` unrolls the groups (bit-identical to
the scanned path — same per-group keys).

Per-tile/per-group RNG keys fold in a CRC of the tile path (init, looped
engine) or of the group's member-path tuple (grouped engine) — NOT an
enumeration index — so a model trained under a mixed plan is bit-identical
to the same tiles trained side by side in separate single-policy trainers.

The same train_step is used single-host and under GSPMD (the dry-run lowers
it with sharded in/out specs; gradients reduce over the data axes before
pulse quantization, so Assumption 3.4 applies to the global gradient).
Passing ``mesh=`` pins the grouped update path to explicit in/out specs —
the stack dim on the ZeRO/data axes, member dims on the model axis — via
shard_map where available (jax >= 0.6) and with_sharding_constraint on
jax 0.4.x (see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
import logging
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import algorithms as alg
from .digital_opt import DigitalOptConfig, ScheduleConfig, apply_opt, init_opt, lr_at
from .paths import path_str
from .plan import AnalogPlan, TilePolicy, legacy_plan, plan_partition
from .tile import (TileBank, TileConfig, _class_member, abstract_tile,
                   abstract_tile_group, group_policies, group_tiles,
                   init_tile, stack_tiles)

logger = logging.getLogger("repro.plan")


def _crc_fold(key, name: str):
    """Fold a stable CRC of ``name`` into ``key`` — path-content keyed RNG
    (independent of enumeration order / co-trained tiles)."""
    return jax.random.fold_in(key, np.uint32(zlib.crc32(name.encode())))

PathPredicate = Callable[[str, Any], bool]
LossFn = Callable[[Any, Any, jax.Array], Tuple[jax.Array, Dict[str, jax.Array]]]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    tile: TileConfig = TileConfig()
    digital: DigitalOptConfig = DigitalOptConfig()
    schedule: ScheduleConfig = ScheduleConfig()
    # gradient accumulation: split the batch into `microbatch` slices and
    # accumulate grads before the (single) pulse update — required to fit
    # activations at LM scale (and keeps Assumption 3.4 applied to the
    # full-batch gradient, as in the single-device math).
    microbatch: int = 1
    accum_dtype: Any = jnp.float32
    # Tile engine. "grouped" (default) stacks tiles by (shape, dtype, rule
    # template) into a TileBank and runs one vmapped begin_step/update per
    # *group*, so the jitted train_step contains O(distinct shapes) copies
    # of the pulse-update graph instead of O(layers). "looped" keeps the
    # legacy per-tile dict layout and Python loop (reference/benchmark
    # baseline; also the layout of pre-TileBank checkpoints).
    engine: str = "grouped"
    # Scan same-structure group classes with jax.lax.scan instead of
    # unrolling one vmapped instance per group: program size stays O(1) in
    # the number of rule-split groups. False unrolls (bit-identical).
    scan_groups: bool = True

    def __post_init__(self):
        assert self.engine in ("grouped", "looped"), self.engine


def default_analog_filter(path: str, leaf) -> bool:
    """Analog-tile every >=2-D weight except embeddings/heads (kept digital,
    as in the paper's setups; see DESIGN.md §5)."""
    if getattr(leaf, "ndim", 0) < 2:
        return False
    lowered = path.lower()
    return not any(s in lowered for s in ("embed", "vocab", "lm_head", "pos"))


def partition_params(params, analog_filter: PathPredicate):
    """Split a param tree into (digital tree w/ None at analog slots,
    {path: leaf} analog dict)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    analog = {}
    dig_leaves = []
    for kp, leaf in flat:
        p = path_str(kp)
        if analog_filter(p, leaf):
            analog[p] = leaf
            dig_leaves.append(None)
        else:
            dig_leaves.append(leaf)
    digital = jax.tree_util.tree_unflatten(treedef, dig_leaves)
    return digital, analog


def _group_tile_cfg(bank: TileBank, group: str, default: TileConfig) -> TileConfig:
    pol = bank.policy(group)
    return pol.tile if (pol is not None and pol.tile is not None) else default


def effective_weights(tiles, tcfg: TileConfig, policies=None) -> Dict[str, jax.Array]:
    """{path: model-space effective weight} for a TileBank (one doubly-
    vmapped effective_weight per class stack, read in place, then static
    ``eff[ci, i]`` slices per member path) or a legacy per-tile dict
    (``policies``: optional {path: TileConfig})."""
    if isinstance(tiles, TileBank):
        out = {}
        pidx = dict(tiles.index)
        for cname, gnames in tiles.class_index:
            gcfg = _group_tile_cfg(tiles, gnames[0], tcfg)
            eff = jax.vmap(jax.vmap(
                lambda ts: alg.effective_weight(ts, gcfg)))(
                    tiles.classes[cname])
            for ci, g in enumerate(gnames):
                for i, p in enumerate(pidx[g]):
                    out[p] = eff[ci, i]
        return out
    policies = policies or {}
    return {p: alg.effective_weight(ts, policies.get(p, tcfg))
            for p, ts in tiles.items()}


def merge_effective(digital, tiles, tcfg: TileConfig, policies=None):
    """Rebuild the full parameter tree with analog leaves replaced by
    their effective (model-space) weights. ``tiles`` is a TileBank (whose
    per-group policies win over ``tcfg``) or a legacy {path: TileState}
    dict."""
    eff = effective_weights(tiles, tcfg, policies)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        digital, is_leaf=lambda x: x is None
    )
    out = []
    for kp, leaf in flat:
        p = path_str(kp)
        if leaf is None and p in eff:
            out.append(eff[p])
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def extract_analog_grads(grads, tiles):
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    agrads = {}
    for kp, leaf in flat:
        p = path_str(kp)
        if p in tiles:
            agrads[p] = leaf
    return agrads


def mask_digital_grads(grads, tiles):
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    out = []
    for kp, leaf in flat:
        out.append(None if path_str(kp) in tiles else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


class TrainState(dict):
    """Pytree: step, key, params (digital; None at analog), tiles, opt."""


jax.tree_util.register_pytree_with_keys(
    TrainState,
    lambda d: (tuple((jax.tree_util.DictKey(k), d[k]) for k in sorted(d)),
               tuple(sorted(d))),
    lambda keys, vals: TrainState(zip(keys, vals)),
)


def _vmap_tile(fn):
    """Lift a per-tile ``fn(tile_state, key, *extras)`` to one group stack:
    vmap over the member axis, wrapping each tile's raw (2,) key data."""
    return jax.vmap(
        lambda ts, kr, *ex: fn(ts, jax.random.wrap_key_data(kr), *ex))


def _stack_rows(results):
    """Restack per-group results into a class-shaped (C, ...) tree (the
    unrolled reference path; singletons use a free expand_dims)."""
    if len(results) == 1:
        return jax.tree.map(lambda l: jnp.expand_dims(l, 0), results[0])
    return jax.tree.map(lambda *ls: jnp.stack(ls), *results)


class AnalogTrainer:
    def __init__(
        self,
        loss_fn: LossFn,
        cfg: TrainerConfig,
        analog_filter: Optional[PathPredicate] = None,
        mesh=None,
        *,
        plan: Optional[AnalogPlan] = None,
    ):
        """``plan``: an AnalogPlan mapping parameter paths to TilePolicies
        (heterogeneous devices/algorithms per path; see core/plan.py and
        the ``repro.api`` facade). When omitted, the deprecated
        ``(cfg.tile, analog_filter)`` pair is mapped onto a one-rule plan
        behind a one-time DeprecationWarning.

        ``mesh``: optional jax.sharding.Mesh. When set, the grouped tile
        phases run under explicit in/out specs (stack dim on the ZeRO/data
        axes, member dims on the model axis per the owning weight's rule);
        when None, layout is left to GSPMD propagation from the caller's
        in_shardings."""
        self.loss_fn = loss_fn
        self.cfg = cfg
        if plan is None:
            plan = legacy_plan(cfg.tile, analog_filter or default_analog_filter)
        elif analog_filter is not None:
            raise ValueError("pass either plan= or analog_filter=, not both")
        self.plan = plan
        self.analog_filter = analog_filter
        self.mesh = mesh
        # {path: TileConfig} resolved against real leaves by init /
        # abstract_state — the looped engine's policy source (the grouped
        # engine carries policies in the TileBank treedef instead)
        self._path_tile_cfgs: Dict[str, TileConfig] = {}

    def _remember_path_cfgs(self, analog, policies) -> None:
        self._path_tile_cfgs.update(
            {p: (policies[p].tile or self.cfg.tile) for p in analog})

    def _tile_cfg_of(self, path: str) -> TileConfig:
        """Static TileConfig of one analog path (looped engine): resolved
        with the leaf at init/abstract_state time when possible (rank
        guards and legacy predicates need the leaf). Paths never seen by
        init — e.g. a restored state stepped without one — re-resolve
        leafless; a rule the plan cannot evaluate without a leaf falls
        back to the trainer default, which is exactly the legacy
        single-policy behavior."""
        cfg = self._path_tile_cfgs.get(path)
        if cfg is not None:
            return cfg
        try:
            pol = self.plan.policy_for(path)
        except Exception:  # leaf-dependent legacy predicate
            return self.cfg.tile
        return pol.tile if (pol is not None and pol.tile is not None) \
            else self.cfg.tile

    def describe_plan(self, params) -> str:
        """One-line plan summary: ``N analog paths -> K groups, algorithms
        {...}, M digital leaves``. Works on abstract params."""
        digital, analog, policies = plan_partition(params, self.plan)
        index = group_tiles({p: analog[p].shape for p in analog},
                            self.cfg.tile, policies)
        pols = group_policies(index, policies) or {}
        algos: Dict[str, int] = {}
        for g, paths in index:
            pol = pols.get(g)
            a = pol.tile.algorithm if pol is not None else self.cfg.tile.algorithm
            algos[a] = algos.get(a, 0) + len(paths)
        n_dig = sum(leaf is not None for leaf in jax.tree.leaves(
            digital, is_leaf=lambda x: x is None))
        algos_s = "{" + ", ".join(f"{a}: {n}" for a, n in sorted(algos.items())) + "}"
        return (f"plan: {len(analog)} analog paths -> {len(index)} groups, "
                f"algorithms {algos_s}, {n_dig} digital leaves")

    def _constrain(self, tree, member_paths, prefix: int = 0):
        if self.mesh is None:
            return tree
        from repro.distributed import sharding as shd

        return shd.constrain_stacked(tree, member_paths, self.mesh,
                                     prefix=prefix)

    def _grouped_apply(self, bank: TileBank, make_vfn, key, extras=()):
        """Apply one stack-level function per scan class, in place.

        ``make_vfn(tcfg)`` returns a *stack-level* function
        ``vfn(group_state, keys_raw, *extra)`` over one (n, *member) group
        stack, specialized to the class's static TileConfig (its TilePolicy
        under a mixed plan, the trainer default otherwise) — usually
        ``_vmap_tile`` of a per-tile function, or ``alg.update_batched``
        for the fused backend. The bank's class storage already carries the
        (C, n, *member) layout ``lax.scan`` wants, so the scanned path
        consumes ``bank.classes`` directly: zero ``jnp.stack`` on entry,
        zero ``leaf[ci]`` gather on exit (the acceptance HLO check counts
        restack concatenates). Per-group keys fold a CRC of the group's
        member-path tuple — identical between the scanned and unrolled
        engines (bit-identical results) and independent of which other
        groups co-train. With a mesh, stacks are pinned to explicit specs:
        shard_map over the stack axis where available (jax >= 0.6),
        with_sharding_constraint + GSPMD otherwise (jax 0.4.x).

        extras: {class-name: (C, n, ...) stacked array} pytrees of
        per-class inputs (analog gradients). Returns {class-name: vfn
        output with a leading class axis} — singleton classes get a free
        ``expand_dims``; ``scan_groups=False`` unrolls per group and
        restacks (the PR-5-equivalent data-movement reference path).

        Classes under a ``update_backend='fused'`` policy skip the scan
        entirely: the class stack IS the batch of a hand-batched update, so
        the (C, n) axes flatten to one (C*n, *member) stack — a free
        reshape on class-keyed storage — and every phase runs as a single
        fused program with no per-iteration slice/scatter. Per-tile key
        streams are position-independent, so this is bit-identical to the
        scanned and unrolled paths.
        """
        index = dict(bank.index)

        def keys_raw(paths):
            kg = _crc_fold(key, "|".join(paths))
            return jax.random.key_data(jax.random.split(kg, len(paths)))

        out = {}
        for cname, gnames in bank.class_index:
            tcfg = _group_tile_cfg(bank, gnames[0], self.cfg.tile)
            vfn = make_vfn(tcfg)
            cstate = bank.classes[cname]
            if tcfg.update_backend == "fused":
                n_c = len(gnames)
                paths_list = tuple(index[g] for g in gnames)
                flat_n = sum(len(ps) for ps in paths_list)
                kr = (jnp.concatenate([keys_raw(ps) for ps in paths_list])
                      if n_c > 1 else keys_raw(paths_list[0]))

                def flat(t):
                    return jax.tree.map(
                        lambda l: l.reshape((-1,) + l.shape[2:]), t)

                args = (self._constrain(flat(cstate), paths_list),
                        kr) + tuple(
                            self._constrain(flat(e[cname]), paths_list)
                            for e in extras)
                res = None
                if self.mesh is not None:
                    from repro.distributed import sharding as shd

                    res = shd.shard_stacked_call(
                        vfn, self.mesh, flat_n, *args)
                if res is None:
                    res = vfn(*args)
                out[cname] = self._constrain(
                    jax.tree.map(
                        lambda l: l.reshape((n_c, flat_n // n_c)
                                            + l.shape[1:]), res),
                    paths_list, prefix=1)
            elif len(gnames) > 1 and self.cfg.scan_groups:
                paths_list = tuple(index[g] for g in gnames)
                kr = jnp.stack([keys_raw(index[g]) for g in gnames])
                cstate = self._constrain(cstate, paths_list, prefix=1)
                ex = tuple(self._constrain(e[cname], paths_list, prefix=1)
                           for e in extras)

                def body(carry, xs):
                    return carry, vfn(*xs)

                _, res = jax.lax.scan(body, (), (cstate, kr, *ex))
                out[cname] = self._constrain(res, paths_list, prefix=1)
            else:
                results = []
                for ci, g in enumerate(gnames):
                    paths = index[g]
                    args = (self._constrain(_class_member(cstate, ci),
                                            paths),
                            keys_raw(paths)) + tuple(
                                self._constrain(
                                    _class_member(e[cname], ci),
                                    paths)
                                for e in extras)
                    res = None
                    if self.mesh is not None:
                        from repro.distributed import sharding as shd

                        res = shd.shard_stacked_call(
                            vfn, self.mesh, len(paths), *args)
                    if res is None:
                        res = vfn(*args)
                    results.append(self._constrain(res, paths))
                out[cname] = _stack_rows(results)
        return out

    # -- state ------------------------------------------------------------
    def init(self, key, params, sp_estimates: Optional[Dict[str, Any]] = None) -> TrainState:
        digital, analog, policies = plan_partition(params, self.plan)
        self._remember_path_cfgs(analog, policies)
        logger.info(self.describe_plan(params))
        per_tile = {}
        for p, w0 in sorted(analog.items()):
            sp = (sp_estimates or {}).get(p)
            per_tile[p] = init_tile(_crc_fold(key, p), w0,
                                    policies[p].tile or self.cfg.tile, sp)
        if self.cfg.engine == "grouped":
            index = group_tiles({p: w.shape for p, w in analog.items()},
                                self.cfg.tile, policies)
            tiles = stack_tiles(per_tile, index,
                                group_policies(index, policies))
        else:
            tiles = per_tile
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.key_data(key).astype(jnp.uint32),
            params=digital,
            tiles=tiles,
            opt=init_opt(digital, self.cfg.digital),
        )

    def abstract_state(self, params_shapes) -> TrainState:
        """ShapeDtypeStruct state (dry-run lowering; no allocation)."""
        digital, analog, policies = plan_partition(params_shapes, self.plan)
        self._remember_path_cfgs(analog, policies)
        if self.cfg.engine == "grouped":
            index = group_tiles({p: w.shape for p, w in analog.items()},
                                self.cfg.tile, policies)
            pols = group_policies(index, policies)
            tiles = TileBank(
                {g: abstract_tile_group(
                    analog[paths[0]].shape, len(paths),
                    (pols or {}).get(g, TilePolicy(self.cfg.tile)).tile)
                 for g, paths in index},
                index,
                pols,
            )
        else:
            tiles = {p: abstract_tile(w.shape, policies[p].tile or self.cfg.tile)
                     for p, w in sorted(analog.items())}
        opt = init_opt(
            jax.tree.map(lambda s: None if s is None else jax.ShapeDtypeStruct(s.shape, jnp.float32),
                         digital, is_leaf=lambda x: x is None),
            self.cfg.digital,
        )
        return TrainState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            key=jax.ShapeDtypeStruct((2,), jnp.uint32),
            params=digital,
            tiles=tiles,
            opt=opt,
        )

    # -- step -------------------------------------------------------------
    def train_step(self, state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        tcfg = self.cfg.tile
        key = jax.random.wrap_key_data(state["key"])
        key, k_begin, k_model, k_upd = jax.random.split(key, 4)
        grouped = isinstance(state["tiles"], TileBank)

        # phase 1: chopper / Q-tilde sync — one vmapped begin_step per
        # group under the group's policy TileConfig, scanned per
        # same-structure same-policy class (grouped engine), or one per
        # tile (legacy looped engine)
        if grouped:
            bank: TileBank = state["tiles"]
            begun = self._grouped_apply(
                bank,
                lambda gcfg: _vmap_tile(
                    lambda ts, k: alg.begin_step(ts, k, gcfg)),
                k_begin)
            tiles = TileBank.from_classes(begun, bank.index, bank.class_index,
                                          bank.policies)
            path_cfgs = None
        else:
            path_cfgs = {p: self._tile_cfg_of(p) for p in state["tiles"]}
            tiles = {
                p: alg.begin_step(ts, _crc_fold(k_begin, p), path_cfgs[p])
                for p, ts in sorted(state["tiles"].items())
            }

        # phase 2: fwd/bwd on effective weights (with grad accumulation)
        eff = merge_effective(state["params"], tiles, tcfg, path_cfgs)
        mb = self.cfg.microbatch
        if mb <= 1:
            (loss, aux), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
                eff, batch, k_model
            )
        else:
            def slice_batch(i):
                return jax.tree.map(
                    lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:])[i]
                    if getattr(x, "ndim", 0) >= 1 else x,
                    batch,
                )

            def mb_step(carry, i):
                g_acc, l_acc, a_acc = carry
                (l, a), g = jax.value_and_grad(self.loss_fn, has_aux=True)(
                    eff, slice_batch(i), jax.random.fold_in(k_model, i)
                )
                g_acc = jax.tree.map(
                    lambda acc, gi: acc + gi.astype(self.cfg.accum_dtype), g_acc, g
                )
                a_acc = jax.tree.map(lambda x, y: x + y, a_acc, a)
                return (g_acc, l_acc + l, a_acc), None

            # first microbatch outside the scan (defines aux structure)
            (l0, a0), g0_ = jax.value_and_grad(self.loss_fn, has_aux=True)(
                eff, slice_batch(0), jax.random.fold_in(k_model, 0)
            )
            g0 = jax.tree.map(lambda g: g.astype(self.cfg.accum_dtype), g0_)
            (grads, loss, aux), _ = jax.lax.scan(
                mb_step, (g0, l0.astype(jnp.float32), a0), jnp.arange(1, mb)
            )
            inv = 1.0 / mb
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            aux = jax.tree.map(lambda x: x * inv, aux)

        lr = lr_at(state["step"], self.cfg.schedule)

        # phase 3a: digital branch
        dgrads = mask_digital_grads(grads, tiles)
        new_params, new_opt, gnorm = apply_opt(
            state["params"], dgrads, state["opt"], state["step"], lr, self.cfg.digital
        )

        # phase 3b: analog branch (pulse updates) — grouped engine runs ONE
        # vmapped pulse-update per group over the stacked state (scanned per
        # same-structure class), with a single split-once-per-group key;
        # looped engine is the legacy O(tiles) unrolled reference.
        agrads = extract_analog_grads(grads, tiles)
        tile_metrics = []  # per-class (C*n,) metric vectors / per-tile scalars
        if grouped:
            # One flat stack + free reshape per class, laid out by the
            # static class index — grads enter the scan in storage order
            # with a single rank-(member+1) concatenate (no per-group
            # restack, no per-step dict re-walk).
            pidx = dict(tiles.index)
            stacked_grads = {}
            for cname, gnames in tiles.class_index:
                flat = [agrads[p] for g in gnames for p in pidx[g]]
                cdims = tiles.classes[cname]["W"].shape[:2]
                arr = (jnp.stack(flat) if len(flat) > 1
                       else jnp.expand_dims(flat[0], 0))
                stacked_grads[cname] = arr.reshape(cdims + flat[0].shape)

            def make_update_vfn(gcfg):
                if gcfg.update_backend == "fused":
                    return lambda ts, kr, grd: alg.update_batched(
                        ts, grd, kr, gcfg, lr)
                return _vmap_tile(
                    lambda ts, k, grd: alg.update(ts, grd, k, gcfg, lr))

            res = self._grouped_apply(
                tiles, make_update_vfn, k_upd, extras=(stacked_grads,))
            new_tiles = TileBank.from_classes(
                {c: res[c][0] for c, _ in tiles.class_index},
                tiles.index, tiles.class_index, tiles.policies)
            tile_metrics = [
                jax.tree.map(lambda v: v.reshape(-1), res[c][1])
                for c, _ in tiles.class_index]
        else:
            new_tiles = {}
            for p, ts in sorted(tiles.items()):
                ts2, m = alg.update(ts, agrads[p], _crc_fold(k_upd, p),
                                    path_cfgs[p], lr)
                new_tiles[p] = ts2
                tile_metrics.append(m)

        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm, **aux}
        if tile_metrics:
            # mixed plans: metric key sets differ per algorithm — aggregate
            # the union over whichever groups emit each key
            keys = sorted({k for m in tile_metrics for k in m})
            for k in keys:
                vals = jnp.concatenate(
                    [jnp.atleast_1d(m[k]) for m in tile_metrics if k in m])
                metrics[f"tile/{k}"] = jnp.sum(vals) if k in ("pulses", "prog_events") else jnp.mean(vals)

        new_state = TrainState(
            step=state["step"] + 1,
            key=jax.random.key_data(key).astype(jnp.uint32),
            params=new_params,
            tiles=new_tiles,
            opt=new_opt,
        )
        return new_state, metrics

    def jit_step(self, donate: bool = True, **jit_kwargs):
        return jax.jit(
            self.train_step,
            donate_argnums=(0,) if donate else (),
            **jit_kwargs,
        )
