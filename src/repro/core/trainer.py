"""AnalogTrainer: wires any JAX model to the analog tile algorithms.

Given a loss function over a parameter pytree and a predicate selecting
which leaves live on analog tiles, builds pure jit-able ``init`` /
``train_step`` functions:

  1. ``begin_step`` phase (chopper draw / Q-tilde sync, Alg.3 l.3-6)
  2. forward/backward on the *effective* parameter tree
     (analog leaves -> scale * W̄, paper's mixed weight)
  3. digital leaves -> SGD/Adam; analog leaves -> pulse-based tile update

Tiles are stored shape-grouped (TileBank): all tiles of one (shape, dtype)
stack along a leading axis and phases 1/3b run as ONE vmapped instance per
group — the jitted train_step contains O(distinct shapes) copies of the
pulse-update graph, not O(layers). ``TrainerConfig(engine="looped")`` keeps
the legacy per-tile dict layout and Python loop as a reference baseline.

The same train_step is used single-host and under GSPMD (the dry-run lowers
it with sharded in/out specs; gradients reduce over the data axes before
pulse quantization, so Assumption 3.4 applies to the global gradient).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import algorithms as alg
from .digital_opt import DigitalOptConfig, ScheduleConfig, apply_opt, init_opt, lr_at
from .paths import path_str
from .tile import (TileBank, TileConfig, abstract_tile, abstract_tile_group,
                   group_tiles, init_tile, stack_tiles)

PathPredicate = Callable[[str, Any], bool]
LossFn = Callable[[Any, Any, jax.Array], Tuple[jax.Array, Dict[str, jax.Array]]]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    tile: TileConfig = TileConfig()
    digital: DigitalOptConfig = DigitalOptConfig()
    schedule: ScheduleConfig = ScheduleConfig()
    # gradient accumulation: split the batch into `microbatch` slices and
    # accumulate grads before the (single) pulse update — required to fit
    # activations at LM scale (and keeps Assumption 3.4 applied to the
    # full-batch gradient, as in the single-device math).
    microbatch: int = 1
    accum_dtype: Any = jnp.float32
    # Tile engine. "grouped" (default) stacks tiles by (shape, dtype) into a
    # TileBank and runs one vmapped begin_step/update per *group*, so the
    # jitted train_step contains O(distinct shapes) copies of the pulse-update
    # graph instead of O(layers). "looped" keeps the legacy per-tile dict
    # layout and Python loop (reference/benchmark baseline; also the layout
    # of pre-TileBank checkpoints).
    engine: str = "grouped"

    def __post_init__(self):
        assert self.engine in ("grouped", "looped"), self.engine


def default_analog_filter(path: str, leaf) -> bool:
    """Analog-tile every >=2-D weight except embeddings/heads (kept digital,
    as in the paper's setups; see DESIGN.md §5)."""
    if getattr(leaf, "ndim", 0) < 2:
        return False
    lowered = path.lower()
    return not any(s in lowered for s in ("embed", "vocab", "lm_head", "pos"))


def partition_params(params, analog_filter: PathPredicate):
    """Split a param tree into (digital tree w/ None at analog slots,
    {path: leaf} analog dict)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    analog = {}
    dig_leaves = []
    for kp, leaf in flat:
        p = path_str(kp)
        if analog_filter(p, leaf):
            analog[p] = leaf
            dig_leaves.append(None)
        else:
            dig_leaves.append(leaf)
    digital = jax.tree_util.tree_unflatten(treedef, dig_leaves)
    return digital, analog


def effective_weights(tiles, tcfg: TileConfig) -> Dict[str, jax.Array]:
    """{path: model-space effective weight} for a TileBank (one vmapped
    effective_weight per shape group) or a legacy per-tile dict."""
    if isinstance(tiles, TileBank):
        out = {}
        for g, paths in tiles.index:
            eff = jax.vmap(lambda ts: alg.effective_weight(ts, tcfg))(
                tiles.groups[g])
            for i, p in enumerate(paths):
                out[p] = eff[i]
        return out
    return {p: alg.effective_weight(ts, tcfg) for p, ts in tiles.items()}


def merge_effective(digital, tiles, tcfg: TileConfig):
    """Rebuild the full parameter tree with analog leaves replaced by
    their effective (model-space) weights. ``tiles`` is a TileBank or a
    legacy {path: TileState} dict."""
    eff = effective_weights(tiles, tcfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        digital, is_leaf=lambda x: x is None
    )
    out = []
    for kp, leaf in flat:
        p = path_str(kp)
        if leaf is None and p in eff:
            out.append(eff[p])
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def extract_analog_grads(grads, tiles):
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    agrads = {}
    for kp, leaf in flat:
        p = path_str(kp)
        if p in tiles:
            agrads[p] = leaf
    return agrads


def mask_digital_grads(grads, tiles):
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    out = []
    for kp, leaf in flat:
        out.append(None if path_str(kp) in tiles else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


class TrainState(dict):
    """Pytree: step, key, params (digital; None at analog), tiles, opt."""


jax.tree_util.register_pytree_with_keys(
    TrainState,
    lambda d: (tuple((jax.tree_util.DictKey(k), d[k]) for k in sorted(d)),
               tuple(sorted(d))),
    lambda keys, vals: TrainState(zip(keys, vals)),
)


class AnalogTrainer:
    def __init__(
        self,
        loss_fn: LossFn,
        cfg: TrainerConfig,
        analog_filter: PathPredicate = default_analog_filter,
    ):
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.analog_filter = analog_filter

    # -- state ------------------------------------------------------------
    def init(self, key, params, sp_estimates: Optional[Dict[str, Any]] = None) -> TrainState:
        digital, analog = partition_params(params, self.analog_filter)
        per_tile = {}
        for i, (p, w0) in enumerate(sorted(analog.items())):
            sp = (sp_estimates or {}).get(p)
            per_tile[p] = init_tile(jax.random.fold_in(key, i), w0, self.cfg.tile, sp)
        if self.cfg.engine == "grouped":
            index = group_tiles({p: w.shape for p, w in analog.items()},
                                self.cfg.tile)
            tiles = stack_tiles(per_tile, index)
        else:
            tiles = per_tile
        return TrainState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.key_data(key).astype(jnp.uint32),
            params=digital,
            tiles=tiles,
            opt=init_opt(digital, self.cfg.digital),
        )

    def abstract_state(self, params_shapes) -> TrainState:
        """ShapeDtypeStruct state (dry-run lowering; no allocation)."""
        digital, analog = partition_params(params_shapes, self.analog_filter)
        if self.cfg.engine == "grouped":
            index = group_tiles({p: w.shape for p, w in analog.items()},
                                self.cfg.tile)
            tiles = TileBank(
                {g: abstract_tile_group(analog[paths[0]].shape, len(paths),
                                        self.cfg.tile)
                 for g, paths in index},
                index,
            )
        else:
            tiles = {p: abstract_tile(w.shape, self.cfg.tile)
                     for p, w in sorted(analog.items())}
        opt = init_opt(
            jax.tree.map(lambda s: None if s is None else jax.ShapeDtypeStruct(s.shape, jnp.float32),
                         digital, is_leaf=lambda x: x is None),
            self.cfg.digital,
        )
        return TrainState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            key=jax.ShapeDtypeStruct((2,), jnp.uint32),
            params=digital,
            tiles=tiles,
            opt=opt,
        )

    # -- step -------------------------------------------------------------
    def train_step(self, state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        tcfg = self.cfg.tile
        key = jax.random.wrap_key_data(state["key"])
        key, k_begin, k_model, k_upd = jax.random.split(key, 4)
        grouped = isinstance(state["tiles"], TileBank)

        # phase 1: chopper / Q-tilde sync — one vmapped begin_step per shape
        # group (grouped engine) or one per tile (legacy looped engine)
        if grouped:
            bank: TileBank = state["tiles"]
            begun = {}
            for gi, (g, paths) in enumerate(bank.index):
                keys = jax.random.split(
                    jax.random.fold_in(k_begin, gi), len(paths))
                begun[g] = jax.vmap(
                    lambda ts, k: alg.begin_step(ts, k, tcfg))(
                        bank.groups[g], keys)
            tiles = TileBank(begun, bank.index)
        else:
            tiles = {
                p: alg.begin_step(ts, jax.random.fold_in(k_begin, i), tcfg)
                for i, (p, ts) in enumerate(sorted(state["tiles"].items()))
            }

        # phase 2: fwd/bwd on effective weights (with grad accumulation)
        eff = merge_effective(state["params"], tiles, tcfg)
        mb = self.cfg.microbatch
        if mb <= 1:
            (loss, aux), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
                eff, batch, k_model
            )
        else:
            def slice_batch(i):
                return jax.tree.map(
                    lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:])[i]
                    if getattr(x, "ndim", 0) >= 1 else x,
                    batch,
                )

            def mb_step(carry, i):
                g_acc, l_acc, a_acc = carry
                (l, a), g = jax.value_and_grad(self.loss_fn, has_aux=True)(
                    eff, slice_batch(i), jax.random.fold_in(k_model, i)
                )
                g_acc = jax.tree.map(
                    lambda acc, gi: acc + gi.astype(self.cfg.accum_dtype), g_acc, g
                )
                a_acc = jax.tree.map(lambda x, y: x + y, a_acc, a)
                return (g_acc, l_acc + l, a_acc), None

            # first microbatch outside the scan (defines aux structure)
            (l0, a0), g0_ = jax.value_and_grad(self.loss_fn, has_aux=True)(
                eff, slice_batch(0), jax.random.fold_in(k_model, 0)
            )
            g0 = jax.tree.map(lambda g: g.astype(self.cfg.accum_dtype), g0_)
            (grads, loss, aux), _ = jax.lax.scan(
                mb_step, (g0, l0.astype(jnp.float32), a0), jnp.arange(1, mb)
            )
            inv = 1.0 / mb
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            aux = jax.tree.map(lambda x: x * inv, aux)

        lr = lr_at(state["step"], self.cfg.schedule)

        # phase 3a: digital branch
        dgrads = mask_digital_grads(grads, tiles)
        new_params, new_opt, gnorm = apply_opt(
            state["params"], dgrads, state["opt"], state["step"], lr, self.cfg.digital
        )

        # phase 3b: analog branch (pulse updates) — grouped engine runs ONE
        # vmapped pulse-update per shape group over the stacked state, with a
        # single split-once-per-group key; looped engine is the legacy
        # O(tiles) unrolled reference.
        agrads = extract_analog_grads(grads, tiles)
        tile_metrics = []  # per-group (n,)-vector metrics / per-tile scalars
        if grouped:
            updated = {}
            for gi, (g, paths) in enumerate(tiles.index):
                gg = jnp.stack([agrads[p] for p in paths])
                keys = jax.random.split(
                    jax.random.fold_in(k_upd, gi), len(paths))
                updated[g], gm = jax.vmap(
                    lambda ts, grd, k: alg.update(ts, grd, k, tcfg, lr))(
                        tiles.groups[g], gg, keys)
                tile_metrics.append(gm)
            new_tiles = TileBank(updated, tiles.index)
        else:
            new_tiles = {}
            for i, (p, ts) in enumerate(sorted(tiles.items())):
                ts2, m = alg.update(ts, agrads[p], jax.random.fold_in(k_upd, i), tcfg, lr)
                new_tiles[p] = ts2
                tile_metrics.append(m)

        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm, **aux}
        if tile_metrics:
            keys = tile_metrics[0].keys()
            for k in keys:
                vals = jnp.concatenate(
                    [jnp.atleast_1d(m[k]) for m in tile_metrics if k in m])
                metrics[f"tile/{k}"] = jnp.sum(vals) if k in ("pulses", "prog_events") else jnp.mean(vals)

        new_state = TrainState(
            step=state["step"] + 1,
            key=jax.random.key_data(key).astype(jnp.uint32),
            params=new_params,
            tiles=new_tiles,
            opt=new_opt,
        )
        return new_state, metrics

    def jit_step(self, donate: bool = True, **jit_kwargs):
        return jax.jit(
            self.train_step,
            donate_argnums=(0,) if donate else (),
            **jit_kwargs,
        )
