"""Digital optimizers for the non-analog parameter branch (pure JAX).

The paper keeps embeddings / norms / biases digital; those leaves are
updated here with SGD(+momentum) or Adam(W), with optional global-norm
clipping and weight decay, plus warmup-cosine LR schedules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DigitalOptConfig:
    kind: str = "sgdm"          # sgd | sgdm | adam | adamw
    lr_scale: float = 1.0       # multiplier on the global LR
    momentum: float = 0.9
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 0.0      # 0 = off


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "constant"      # constant | cosine | linear
    base_lr: float = 0.1
    warmup_steps: int = 0
    total_steps: int = 1000
    min_ratio: float = 0.1


def lr_at(step, cfg: ScheduleConfig):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    base = jnp.float32(cfg.base_lr)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    else:
        warm = 1.0
    if cfg.kind == "constant":
        decay = 1.0
    elif cfg.kind == "cosine":
        frac = jnp.clip((s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = cfg.min_ratio + (1 - cfg.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.kind == "linear":
        frac = jnp.clip((s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 1.0 - (1 - cfg.min_ratio) * frac
    else:
        raise ValueError(cfg.kind)
    return base * warm * decay


def init_opt(params, cfg: DigitalOptConfig) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32) if p is not None else None, params)
    if cfg.kind in ("sgdm",):
        return {"mu": zeros()}
    if cfg.kind in ("adam", "adamw"):
        return {"mu": zeros(), "nu": zeros()}
    return {}


def clip_by_global_norm(grads, max_norm: float):
    leaves = [g for g in jax.tree.leaves(grads) if g is not None]
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: g * scale if g is not None else None, grads), gnorm


def apply_opt(params, grads, opt, step, lr, cfg: DigitalOptConfig):
    """Update the digital branch. ``None`` leaves (analog slots) pass through."""
    lr = lr * cfg.lr_scale
    gnorm = jnp.zeros((), jnp.float32)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(fn):
        return jax.tree.map(
            lambda *xs: None if xs[0] is None else fn(*xs),
            params, grads, *(opt[k] for k in sorted(opt)),
        )

    if cfg.kind == "sgd":
        new_params = upd(lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype))
        return new_params, opt, gnorm
    if cfg.kind == "sgdm":
        new_mu = upd(lambda p, g, m: cfg.momentum * m + g.astype(jnp.float32))
        pairs = jax.tree.map(
            lambda p, m: None if p is None else (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_mu)
        return pairs, {"mu": new_mu}, gnorm
    if cfg.kind in ("adam", "adamw"):
        t = step.astype(jnp.float32) + 1.0
        new_mu = jax.tree.map(
            lambda g, m: None if g is None else cfg.beta1 * m + (1 - cfg.beta1) * g.astype(jnp.float32),
            grads, opt["mu"])
        new_nu = jax.tree.map(
            lambda g, v: None if g is None else cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g.astype(jnp.float32)),
            grads, opt["nu"])
        bc1 = 1 - cfg.beta1 ** t
        bc2 = 1 - cfg.beta2 ** t

        def adam_step(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.kind == "adamw" and cfg.weight_decay > 0:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(
            lambda p, m, v: None if p is None else adam_step(p, m, v),
            params, new_mu, new_nu)
        return new_params, {"mu": new_mu, "nu": new_nu}, gnorm
    raise ValueError(cfg.kind)
