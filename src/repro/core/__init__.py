"""Core analog in-memory training library (the paper's contribution).

  device.py      — resistive device models + d2d/c2c sampling + SP ground truth
  pulse.py       — Analog Update (eq. 2) pulse engine (fused / pulse-train)
  zs.py          — zero-shifting SP calibration (Algorithm 1)
  tile.py        — analog tile state bundle + config
  plan.py        — AnalogPlan / TilePolicy: per-path policy rules
  algorithms.py  — SGD / TT-v1 / TT-v2 / AGAD / Residual / RIDER / E-RIDER
  digital_opt.py — digital-branch optimizers + LR schedules
  trainer.py     — AnalogTrainer: model <-> tiles wiring, jit train_step
"""
from . import algorithms, device, digital_opt, plan, pulse, tile, trainer, zs  # noqa: F401
from .device import PRESETS, DeviceConfig, sample_device, symmetric_point  # noqa: F401
from .plan import DIGITAL, AnalogPlan, TilePolicy  # noqa: F401
from .tile import TileConfig, init_tile  # noqa: F401
from .trainer import AnalogTrainer, TrainerConfig  # noqa: F401
