"""Sharded checkpointing with async save, integrity manifest and elastic
restore (resharding to a different mesh on load).

Format (layout v4): one directory per step:
  step_000123/
    manifest.json   — {path: {shape, dtype, file, crc32}}, step, timestamp;
                      "tile_groups" records, for every TileBank stack, its
                      member weight-paths in stacking order and the resolved
                      TilePolicy (devices + algorithm + hyper-parameters)
                      that trained it; "tile_classes" records each scan
                      class's member groups in class-stack order (with
                      their per-slot member paths) — so restore re-keys
                      stacks from the checkpoint's own layout instead of
                      reconstructing the order from the restore template,
                      and a checkpoint is self-describing about the plan
                      that produced it.
    arrays_000.npz  — leaf arrays keyed by their tree path (chunked ~512MB)

Layout v4 (class-keyed TileBank storage) writes tile leaves as
``tiles/<class>/<slot>`` with a (C, n, *member) shape — one array per scan
class, exactly the zero-copy form the grouped engine trains on. Restore
upgrades any older layout on the fly (see the re-key matrix in
docs/architecture.md): v3 per-group stacks, v2 coarser-keyed stacks and v1
per-tile checkpoints all assemble into v4 class stacks bit-identically, and
a v4 checkpoint restores into any differently-partitioned template
(replanned policies, v3-era per-group consumers) by slicing the class
stacks back apart.

Restore takes a *template* pytree (abstract or concrete) and returns arrays
device_put with the caller's shardings — so a checkpoint written on one mesh
restores onto any other mesh (elastic scaling), or on CPU for inspection.
Preemption-safe: writes go to a tmp dir and are atomically renamed; a
``latest`` symlink is updated last.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.paths import npz_key, path_str

_CHUNK_BYTES = 512 * 1024 * 1024


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {
        path_str(kp): leaf for kp, leaf in flat
    }


def _tile_group_manifest(tree) -> Dict[str, Any]:
    """Per-group member paths + resolved policy of every TileBank in
    ``tree`` (manifest layout v3+). Member order IS the stacking order."""
    from repro.core.plan import policy_to_json
    from repro.core.tile import TileBank

    out: Dict[str, Any] = {}

    def visit(x):
        if isinstance(x, TileBank):
            for g, paths in x.index:
                pol = x.policy(g)
                out[g] = {
                    "members": list(paths),
                    "policy": policy_to_json(pol) if pol is not None else None,
                }
        return None

    jax.tree.map(visit, tree, is_leaf=lambda x: isinstance(x, TileBank))
    return out


def _tile_class_manifest(tree) -> Dict[str, Any]:
    """Per-class member groups (in class-stack order) and their member
    weight-paths for every TileBank in ``tree`` (manifest layout v4). Row
    ``ci`` of a class array is the stack of ``members[ci]``."""
    from repro.core.tile import TileBank

    out: Dict[str, Any] = {}

    def visit(x):
        if isinstance(x, TileBank):
            pidx = dict(x.index)
            for cname, gnames in x.class_index:
                out[cname] = {
                    "groups": list(gnames),
                    "members": [list(pidx[g]) for g in gnames],
                }
        return None

    jax.tree.map(visit, tree, is_leaf=lambda x: isinstance(x, TileBank))
    return out


def save(tree, directory: str, step: int, *, asynchronous: bool = False,
         extra: Optional[Dict[str, Any]] = None) -> Optional[threading.Thread]:
    """Write a checkpoint. With asynchronous=True the device->host copy
    happens immediately but file IO runs on a daemon thread.

    ``extra``: JSON-serializable keys merged into manifest.json (e.g. the
    ``gdc_signatures`` t0 weight signatures ``repro.lifetime`` compares
    against at serve time). Reserved layout keys cannot be overridden."""
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items() if v is not None}
    tile_groups = _tile_group_manifest(tree)
    tile_classes = _tile_class_manifest(tree)
    reserved = {"step", "time", "layout", "arrays", "tile_groups",
                "tile_classes"}
    extra = dict(extra or {})
    assert not (set(extra) & reserved), \
        f"extra manifest keys collide with layout keys: {set(extra) & reserved}"

    def _write():
        # unique tmp dir: an async save and a final sync save of the same
        # step must not collide
        tmp = os.path.join(
            directory, f".tmp_step_{step:09d}_{os.getpid()}_{threading.get_ident()}")
        final = os.path.join(directory, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        manifest: Dict[str, Any] = {"step": step, "time": time.time(),
                                    "layout": 4, "arrays": {}}
        if tile_groups:
            manifest["tile_groups"] = tile_groups
        if tile_classes:
            manifest["tile_classes"] = tile_classes
        manifest.update(extra)
        chunk_idx, chunk, chunk_bytes = 0, {}, 0

        def flush():
            nonlocal chunk_idx, chunk, chunk_bytes
            if not chunk:
                return
            fname = f"arrays_{chunk_idx:03d}.npz"
            np.savez(os.path.join(tmp, fname), **chunk)
            chunk_idx += 1
            chunk, chunk_bytes = {}, 0

        for key, arr in sorted(host.items()):
            safe = npz_key(key)
            manifest["arrays"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "file": f"arrays_{chunk_idx:03d}.npz",
                "npz_key": safe,
                "crc32": zlib.crc32(arr.tobytes()),
            }
            chunk[safe] = arr
            chunk_bytes += arr.nbytes
            if chunk_bytes >= _CHUNK_BYTES:
                flush()
        flush()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            old = final + f".old_{os.getpid()}_{threading.get_ident()}"
            os.rename(final, old)
        try:
            os.rename(tmp, final)
        except OSError:
            # another writer won the race for this step; ours is equivalent
            import shutil

            shutil.rmtree(tmp, ignore_errors=True)
        latest = os.path.join(directory, "latest")
        tmp_link = latest + ".tmp"
        if os.path.lexists(tmp_link):
            os.remove(tmp_link)
        os.symlink(os.path.basename(final), tmp_link)
        os.replace(tmp_link, latest)

    if asynchronous:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def read_manifest(directory: str, step: Optional[int] = None) -> Dict[str, Any]:
    """Load manifest.json of ``step`` (default: latest) — the cheap way to
    read checkpoint metadata (stored plan, ``extra`` keys like the GDC t0
    signatures) without touching any array chunk."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints in {directory}"
    with open(os.path.join(directory, f"step_{step:09d}", "manifest.json")) as f:
        return json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and ".old" not in d
    ]
    return max(steps) if steps else None


def _legacy_group_members(manifest, shape, dtype_name, tag=""):
    """Member weight-paths of one tile group in a legacy per-tile
    checkpoint — sorted, which is exactly the stacking order
    ``repro.core.tile.group_tiles`` uses. A non-empty ``tag`` keeps only
    paths whose sharding-rule template matches (spec-aware group keys)."""
    import re

    members = []
    for key, meta in manifest["arrays"].items():
        m = re.match(r"^tiles/(.+)/W$", key)
        if m and tuple(meta["shape"]) == tuple(shape) \
                and meta["dtype"] == dtype_name:
            members.append(m.group(1))
    if tag:
        from repro.distributed.sharding import rule_template, template_tag

        members = [p for p in members
                   if template_tag(rule_template(p, len(shape))) == tag]
    return sorted(members)


def _bank_member_index(template):
    """{group name: member weight-paths} of every TileBank in ``template``
    (the restore target). Member paths live in the bank's static index, not
    in its leaves, so the re-keying upgrade path reads them here."""
    from repro.core.tile import TileBank

    members = {}

    def visit(x):
        if isinstance(x, TileBank):
            for g, paths in x.index:
                members[g] = tuple(paths)
        return None

    jax.tree.map(visit, template,
                 is_leaf=lambda x: isinstance(x, TileBank))
    return members


def _legacy_grouped_arr(key, manifest, load_arr, bank_members):
    """Assemble a grouped-layout leaf ``tiles/<group>/<slot>`` missing from
    the manifest by upgrading any older layout:

    * per-tile (pre-TileBank) checkpoints: stack the group's member tiles
      in group order;
    * coarser-keyed grouped checkpoints — (shape, dtype)-only stacks
      (pre-spec-aware keys) or single-policy stacks without the policy tag
      (pre-AnalogPlan) — gather the rows belonging to this group's members
      out of the old combined stack. The old stacking order comes from the
      checkpoint's own ``tile_groups`` member manifest when present
      (layout v3); only manifests that predate it fall back to
      reconstructing the order from the restore template's union (which
      assumes the same model);
    * any other regrouping a v3 member manifest can describe — e.g. a
      mixed-plan checkpoint's policy-split stacks restoring into a
      coarser single-policy template — assembled member by member from
      each tile's stored (group, row).

    Returns None when ``key`` is not a grouped tile leaf.
    """
    import re

    from repro.core.tile import group_name, parse_group_name

    m = re.match(r"^tiles/([^/]+)/(.+)$", key)
    if not m:
        return None
    gname = m.group(1)
    parsed = parse_group_name(gname)
    if parsed is None:
        return None
    shape, dtype_name, tag, _ptag = parsed
    slot = m.group(2)
    manifest_groups = manifest.get("tile_groups", {})
    members = bank_members.get(gname) \
        or manifest_groups.get(gname, {}).get("members") \
        or _legacy_group_members(manifest, shape, dtype_name, tag)
    if not members:
        return None
    # 1) per-tile legacy layout
    if f"tiles/{members[0]}/{slot}" in manifest["arrays"]:
        return np.stack([load_arr(f"tiles/{p}/{slot}") for p in members])
    # 2) coarser-keyed grouped layouts: re-key the old stack. Candidates,
    # most specific first: same (shape, dtype, template) without the policy
    # tag (pre-AnalogPlan single-policy), then (shape, dtype) only (PR-1).
    candidates = []
    for cand in (group_name(shape, dtype_name, tag),
                 group_name(shape, dtype_name)):
        if cand != gname and cand not in candidates:
            candidates.append(cand)
    for src in candidates:
        if f"tiles/{src}/{slot}" not in manifest["arrays"]:
            continue
        old_members = manifest_groups.get(src, {}).get("members")
        if old_members is None:
            # pre-v3 manifest: the old member set is the union of the
            # template's groups that the old key covered (same model,
            # regrouped), sorted — the old stacking order.
            sshape, sdt, sttag, _ = parse_group_name(src)
            old_members = sorted(
                p for g, paths in bank_members.items()
                for p in paths
                if (lambda pg: pg is not None and pg[0] == sshape
                    and pg[1] == sdt
                    and (not sttag or pg[2] == sttag))(parse_group_name(g)))
        if not all(p in old_members for p in members):
            continue
        old = load_arr(f"tiles/{src}/{slot}")
        assert old.shape[0] == len(old_members), (
            f"legacy group {src} holds {old.shape[0]} tiles but its member "
            f"list names {len(old_members)}: {old_members}")
        return old[[old_members.index(p) for p in members]]
    # 3) cross-plan re-key via the layout-v3 member map: the checkpoint's
    # own tile_groups manifest names every tile's (group, row), so the
    # template group can be assembled member by member from ANY regrouping
    # — e.g. a mixed-plan checkpoint (policy-split stacks) restoring into
    # a coarser single-policy template merges the split stacks back.
    path_src: Dict[str, tuple] = {}
    for src, rec in manifest_groups.items():
        if f"tiles/{src}/{slot}" not in manifest["arrays"]:
            continue
        for row, p2 in enumerate(rec.get("members") or ()):
            path_src.setdefault(p2, (src, row))
    if not all(p in path_src for p in members):
        return None
    loaded: Dict[str, Any] = {}  # each source stack decompresses ONCE
    rows = []
    for p in members:
        src, row = path_src[p]
        if src not in loaded:
            loaded[src] = load_arr(f"tiles/{src}/{slot}")
        rows.append(loaded[src][row])
    return np.stack(rows)


def _group_view(manifest, load_arr):
    """Per-group view of a v4 class-keyed checkpoint: returns a
    ``(manifest', load_arr')`` pair in which every ``tiles/<group>/<slot>``
    of every class member exists as a virtual array (a static ``[ci]``
    slice of its class stack). All pre-v4 re-key strategies
    (``_legacy_grouped_arr``) then work against a v4 source unchanged —
    this is the v4 -> v3-partition fallback direction of the re-key
    matrix. Checkpoints without ``tile_classes`` pass through untouched."""
    import re

    classes = manifest.get("tile_classes")
    if not classes:
        return manifest, load_arr
    arrays = dict(manifest["arrays"])
    virtual: Dict[str, tuple] = {}
    for key, meta in manifest["arrays"].items():
        m = re.match(r"^tiles/([^/]+)/(.+)$", key)
        if not m or m.group(1) not in classes:
            continue
        cname, slot = m.group(1), m.group(2)
        for ci, g in enumerate(classes[cname]["groups"]):
            gkey = f"tiles/{g}/{slot}"
            # single-group classes (cname == g) are overridden too: the
            # group view always has the (n, *member) member shape
            virtual[gkey] = (key, ci)
            arrays[gkey] = {**meta, "shape": list(meta["shape"][1:])}
    man2 = dict(manifest)
    man2["arrays"] = arrays

    def load2(key):
        v = virtual.get(key)
        if v is None:
            return load_arr(key)
        return load_arr(v[0])[v[1]]

    return man2, load2


def _class_arr(key, manifest, load_arr, bank_members):
    """Assemble a v4 class-keyed leaf ``tiles/<class>/<slot>`` that is not
    stored under its own key, by stacking its member groups — each group
    coming from a same-name v3 stack, a re-keyed older layout
    (``_legacy_grouped_arr``), or a slice of a differently-partitioned v4
    class (``_group_view``). Returns None when ``key`` is not a class
    leaf or a member group cannot be assembled."""
    import re

    from repro.core.tile import parse_class_name, parse_group_name

    m = re.match(r"^tiles/([^/]+)/(.+)$", key)
    if not m:
        return None
    cname, slot = m.group(1), m.group(2)
    groups = parse_class_name(cname)
    if any(parse_group_name(g) is None for g in groups):
        return None
    gman, gload = _group_view(manifest, load_arr)
    parts = []
    for g in groups:
        gkey = f"tiles/{g}/{slot}"
        if gkey in gman["arrays"]:
            arr = gload(gkey)
        else:
            arr = _legacy_grouped_arr(gkey, gman, gload, bank_members)
        if arr is None:
            return None
        parts.append(arr)
    return np.stack(parts)


def _policy_json_matches(new, stored) -> bool:
    """Tolerant policy comparison: only keys the checkpoint actually
    recorded constrain the match, so TileConfig fields added after the
    checkpoint was written (e.g. ``update_backend``) compare as their
    defaults instead of flagging every old checkpoint as mismatched."""
    if isinstance(new, dict) and isinstance(stored, dict):
        return all(_policy_json_matches(new.get(k), v)
                   for k, v in stored.items())
    return new == stored


def _warn_policy_mismatch(template, manifest) -> None:
    """Emit ONE consolidated warning listing every template stack whose
    TilePolicy differs from the policy the checkpoint records for it
    (layout v3+ manifests only) — large mixed plans would otherwise spam
    one warning per stack. Groups absent from the manifest under their own
    name compare against the coarser legacy key they would re-key from
    (``_legacy_grouped_arr``'s candidate order), so retraining a
    single-policy checkpoint under a different mixed plan warns too."""
    from repro.core.plan import policy_to_json
    from repro.core.tile import TileBank, group_name, parse_group_name

    stored = manifest.get("tile_groups", {})
    if not stored:
        return

    def stored_policies(g):
        if g in stored:
            return [stored[g].get("policy")]
        parsed = parse_group_name(g)
        if parsed is None:
            return []
        shape, dtype_name, tag, _ptag = parsed
        # coarser source the re-key would read from ...
        for cand in (group_name(shape, dtype_name, tag),
                     group_name(shape, dtype_name)):
            if cand in stored:
                return [stored[cand].get("policy")]
        # ... or finer (policy-split) stacks covering the same structure
        return [rec.get("policy") for g2, rec in stored.items()
                if (parse_group_name(g2) or (None,) * 3)[:3]
                == (shape, dtype_name, tag)]

    mismatched = []

    def visit(x):
        if isinstance(x, TileBank):
            for g, _ in x.index:
                pol = x.policy(g)
                if pol is None:
                    continue
                for rec in stored_policies(g):
                    if rec is not None and not _policy_json_matches(
                            policy_to_json(pol), rec):
                        mismatched.append(
                            f"{g} ({rec.get('name') or rec.get('tag')}"
                            f" -> {pol.name or pol.tag})")
                        break
        return None

    jax.tree.map(visit, template, is_leaf=lambda x: isinstance(x, TileBank))
    if mismatched:
        warnings.warn(
            f"{len(mismatched)} tile stack(s) restore under a different "
            f"policy than the one they were trained with: "
            f"{'; '.join(mismatched)}",
            stacklevel=3)


def restore(template, directory: str, step: Optional[int] = None, *,
            shardings=None, verify: bool = False):
    """Load arrays into the structure of ``template``.

    shardings: optional matching pytree of NamedShardings (elastic restore —
    the stored full arrays are device_put with the *new* mesh's shardings).

    Class-keyed tile state (``tiles/<class>/...`` with (C, n, *member)
    leaves, layout v4) restores from any layout: same-layout checkpoints
    load directly; v3 per-group stacks assemble into class stacks group by
    group; legacy per-tile checkpoints are upgraded by stacking their
    member tiles in group order; coarser-keyed stacks — (shape,
    dtype)-only (pre-spec-aware keys) or untagged single-policy stacks
    (pre-AnalogPlan) — are re-keyed by gathering each group's member rows
    out of the old combined stack, using the checkpoint's own
    ``tile_groups`` member manifest when present; and a v4 checkpoint
    restores into a differently-partitioned template by slicing its class
    stacks back into per-group arrays (``_group_view``). Stored per-group
    policies that differ from the restore template's are reported in one
    consolidated warning (restoring a checkpoint into a different plan is
    legal but usually a mistake).
    """
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints in {directory}"
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _warn_policy_mismatch(template, manifest)
    files: Dict[str, Any] = {}

    def load_arr(key):
        meta = manifest["arrays"][key]
        fname = meta["file"]
        if fname not in files:
            files[fname] = np.load(os.path.join(d, fname))
        arr = files[fname][meta["npz_key"]]
        if verify:
            assert zlib.crc32(arr.tobytes()) == meta["crc32"], f"corrupt leaf {key}"
        return arr

    bank_members = _bank_member_index(template)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: x is None
    )
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in jax.tree_util.tree_flatten_with_path(
            shardings, is_leaf=lambda x: x is None)[0]]
    out = []
    for i, (kp, leaf) in enumerate(flat):
        key = path_str(kp)
        if leaf is None:
            out.append(None)
            continue
        expect = tuple(leaf.shape)
        if key in manifest["arrays"] and \
                tuple(manifest["arrays"][key]["shape"]) == expect:
            arr = load_arr(key)
        else:
            arr = _class_arr(key, manifest, load_arr, bank_members)
            if arr is None:
                arr = _legacy_grouped_arr(key, manifest, load_arr,
                                          bank_members)
            if arr is None and key in manifest["arrays"]:
                arr = load_arr(key)  # let the shape assert report it
            assert arr is not None, f"checkpoint missing leaf {key}"
        assert tuple(arr.shape) == expect, (key, arr.shape, expect)
        if shard_flat is not None and shard_flat[i] is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
