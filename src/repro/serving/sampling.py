"""Sampling + feed building shared by every serve driver.

``serve.py`` used to hardcode ``jnp.argmax`` greedy sampling inline in two
places (the prefill tail and the decode step) and rebuild the zero ``frames``
buffer for frontend models on every batch; both now live here.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def sample_greedy(logits) -> jnp.ndarray:
    """Greedy next token from (B, S, V) logits: argmax over the vocabulary
    at the last position, shaped (B, 1) int32 for the decode step."""
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


class FeedBuilder:
    """Builds the prefill feed for a token batch, caching the zero frames
    buffer per (batch, seq) shape instead of reallocating it per call."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._frames: Dict[Tuple[int, int], jnp.ndarray] = {}

    def __call__(self, tokens) -> Dict[str, jnp.ndarray]:
        tokens = jnp.asarray(tokens, jnp.int32)
        feed = {"tokens": tokens}
        if self.cfg.frontend:
            key = tokens.shape[:2]
            if key not in self._frames:
                self._frames[key] = jnp.zeros(
                    key + (self.cfg.d_model,), self.cfg.dtype)
            feed["frames"] = self._frames[key]
        return feed
