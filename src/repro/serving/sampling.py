"""Sampling + feed building shared by every serve driver.

``serve.py`` used to hardcode ``jnp.argmax`` greedy sampling inline in two
places (the prefill tail and the decode step) and rebuild the zero ``frames``
buffer for frontend models on every batch; both now live here.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def sample_greedy(logits) -> jnp.ndarray:
    """Greedy next token from (B, S, V) logits: argmax over the vocabulary
    at the last position, shaped (B, 1) int32 for the decode step."""
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


def sample_topk(logits, temperature: float, k: int, key) -> jnp.ndarray:
    """Temperature + top-k next token from (B, S, V) logits at the last
    position, shaped (B, 1) int32.

    ``key`` is a batch of per-lane PRNG keys, shape (B,) (each lane draws
    from its own request-seeded stream).  ``k`` is static: 0 disables the
    top-k filter (pure temperature sampling); ``temperature`` <= 0 falls
    back to greedy so a single jitted signature serves both.
    """
    last = logits[:, -1].astype(jnp.float32)                          # (B,V)
    if temperature <= 0.0:
        return jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
    scaled = last / jnp.float32(temperature)
    if k > 0 and k < last.shape[-1]:
        kth = jax.lax.top_k(scaled, k)[0][:, -1:]                     # (B,1)
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    tok = jax.vmap(lambda kk, row: jax.random.categorical(kk, row))(key, scaled)
    return tok.astype(jnp.int32)[:, None]


def lane_keys(seeds, pos) -> jnp.ndarray:
    """Per-lane PRNG keys from per-request ``seeds`` (B,) and the lane's
    current ``pos`` (B,): fold the position into the seeded stream so every
    sampled token gets a fresh, replayable key."""
    def one(seed, p):
        return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(0), seed), p)
    return jax.vmap(one)(seeds, pos)


class FeedBuilder:
    """Builds the prefill feed for a token batch, caching the zero frames
    buffer per (batch, seq) shape instead of reallocating it per call."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._frames: Dict[Tuple[int, int], jnp.ndarray] = {}

    def __call__(self, tokens) -> Dict[str, jnp.ndarray]:
        tokens = jnp.asarray(tokens, jnp.int32)
        feed = {"tokens": tokens}
        if self.cfg.frontend:
            key = tokens.shape[:2]
            if key not in self._frames:
                self._frames[key] = jnp.zeros(
                    key + (self.cfg.d_model,), self.cfg.dtype)
            feed["frames"] = self._frames[key]
        return feed
