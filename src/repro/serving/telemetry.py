"""Serving observability: per-request latency, JSON logs, run manifest.

Every request gets a timeline (submitted / admitted / first token / finished)
from which TTFT (time to first token), TPOT (time per output token after the
first) and end-to-end latency derive; ``summarize`` reduces a population to
p50/p99/mean/max with numpy-compatible linear-interpolation percentiles.

All wall-clock reads go through an injectable ``clock`` so tests drive
synthetic timelines deterministically.  Every emitted log line and the final
manifest are validated against ``serving.schema`` at emission time — schema
drift fails the producer, not just the consumer.
"""
from __future__ import annotations

import dataclasses
import json
import time
import uuid
from typing import Any, Callable, Dict, IO, List, Optional

from . import schema


# ---------------------------------------------------------------------------
# percentiles (numpy 'linear' interpolation, dependency-free)
# ---------------------------------------------------------------------------


def percentile(values: List[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation between closest
    ranks — matches ``numpy.percentile(..., method='linear')``."""
    if not values:
        raise ValueError("percentile of empty population")
    xs = sorted(values)
    rank = (len(xs) - 1) * (q / 100.0)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def summarize(values: List[float]) -> Dict[str, float]:
    return {
        "p50": percentile(values, 50.0),
        "p99": percentile(values, 99.0),
        "mean": sum(values) / len(values),
        "max": max(values),
    }


# ---------------------------------------------------------------------------
# per-request timelines
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestTimeline:
    request_id: str
    prompt_len: int = 0
    submitted_s: float = 0.0
    admitted_s: float = 0.0
    first_token_s: float = 0.0
    last_token_s: float = 0.0
    finished_s: float = 0.0
    n_tokens: int = 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.submitted_s

    @property
    def tpot_s(self) -> float:
        """Seconds per output token after the first (0 for 1-token runs)."""
        if self.n_tokens <= 1:
            return 0.0
        return (self.finished_s - self.first_token_s) / (self.n_tokens - 1)

    @property
    def e2e_s(self) -> float:
        return self.finished_s - self.submitted_s


class JsonLogger:
    """Schema-validated structured JSON logging (one object per line)."""

    def __init__(self, sink: Optional[IO[str]] = None):
        self.sink = sink
        self.lines: List[Dict[str, Any]] = []

    def emit(self, line: Dict[str, Any]) -> None:
        schema.validate_log_line(line)
        self.lines.append(line)
        if self.sink is not None:
            self.sink.write(json.dumps(line, sort_keys=True) + "\n")
            self.sink.flush()


class Telemetry:
    """Collects request timelines and engine counters, emits log lines, and
    writes the run-artifact manifest at shutdown."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 log_sink: Optional[IO[str]] = None, log_path: str = ""):
        self._t0 = clock()
        self._clock = clock
        self.log_path = log_path
        self._own_sink = None
        if log_sink is None and log_path:
            self._own_sink = log_sink = open(log_path, "w")
        self.logger = JsonLogger(log_sink)
        self.timelines: Dict[str, RequestTimeline] = {}
        self.steps = 0
        self.prefills = 0            # completed request prefills
        self.prefill_batches = 0     # jitted bucketed prefill dispatches
        self.chunks = 0              # prefill segments (chunked or whole)
        self.retraces = 0            # distinct (len, batch) bucket signatures
        self.gaps: List[float] = []  # pooled inter-token intervals (jitter)
        self.run_id = uuid.uuid4().hex[:12]

    def now(self) -> float:
        return self._clock() - self._t0

    # ------------------------------------------------------------- events
    def request_submitted(self, request_id: str, prompt_len: int,
                          max_new_tokens: int, arrival_step: int = 0) -> None:
        t = self.now()
        self.timelines[request_id] = RequestTimeline(
            request_id, prompt_len=prompt_len, submitted_s=t)
        self.logger.emit({"ts": t, "event": "request_submitted",
                          "request_id": request_id, "prompt_len": prompt_len,
                          "max_new_tokens": max_new_tokens,
                          "arrival_step": arrival_step})

    def request_admitted(self, request_id: str, lane: int, n_pages: int,
                         step: int, shared_pages: int = 0,
                         chunks: int = 1) -> None:
        t = self.now()
        self.timelines[request_id].admitted_s = t
        line = {"ts": t, "event": "request_admitted",
                "request_id": request_id, "lane": lane,
                "n_pages": n_pages, "step": step}
        if shared_pages or chunks > 1:
            line["shared_pages"] = shared_pages
            line["chunks"] = chunks
        self.logger.emit(line)

    def prefill_batch(self, step: int, bucket: int, batch: int) -> None:
        """One bucketed prefill dispatch: ``batch`` rows padded to length
        ``bucket`` ran through a single jitted call."""
        self.logger.emit({"ts": self.now(), "event": "prefill_batch",
                          "step": step, "bucket": bucket, "batch": batch})

    def first_token(self, request_id: str) -> None:
        tl = self.timelines[request_id]
        tl.first_token_s = tl.last_token_s = self.now()
        tl.n_tokens = 1

    def token(self, request_id: str) -> None:
        tl = self.timelines[request_id]
        t = self.now()
        tl.n_tokens += 1
        self.gaps.append(t - tl.last_token_s)
        tl.last_token_s = t

    def request_finished(self, request_id: str, lane: int, step: int) -> None:
        tl = self.timelines[request_id]
        tl.finished_s = self.now()
        self.logger.emit({"ts": tl.finished_s, "event": "request_finished",
                          "request_id": request_id, "lane": lane,
                          "n_tokens": tl.n_tokens, "ttft_s": tl.ttft_s,
                          "tpot_s": tl.tpot_s, "e2e_s": tl.e2e_s,
                          "step": step})

    def engine_stats(self, step: int, active_lanes: int, waiting: int,
                     free_pages: int) -> None:
        self.logger.emit({"ts": self.now(), "event": "engine_stats",
                          "step": step, "active_lanes": active_lanes,
                          "waiting": waiting, "free_pages": free_pages})

    # ------------------------------------------------------------ summary
    def finished(self) -> List[RequestTimeline]:
        return [tl for tl in self.timelines.values() if tl.finished_s > 0]

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        done = self.finished()
        if not done:
            zero = {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
            return {"ttft": dict(zero), "tpot": dict(zero), "e2e": dict(zero)}
        out = {
            "ttft": summarize([tl.ttft_s for tl in done]),
            "tpot": summarize([tl.tpot_s for tl in done]),
            "e2e": summarize([tl.e2e_s for tl in done]),
        }
        # per-request TPOT averages away intra-request stalls; the pooled
        # inter-token intervals expose them (what chunked prefill shrinks)
        if self.gaps:
            out["gap"] = summarize(self.gaps)
        return out

    def generated_tokens(self) -> int:
        return sum(tl.n_tokens for tl in self.timelines.values())

    def run_summary(self, wall_s: float,
                    extras: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        toks = self.generated_tokens()
        line = {"ts": self.now(), "event": "run_summary",
                "requests": len(self.timelines), "generated_tokens": toks,
                "wall_s": wall_s,
                "tokens_per_s": toks / wall_s if wall_s > 0 else 0.0}
        if extras:
            line.update(extras)
        self.logger.emit(line)
        return line

    # ----------------------------------------------------------- manifest
    def build_manifest(self, *, arch: str, engine: Dict[str, Any],
                       checkpoint: Dict[str, Any], wall_s: float,
                       status: str = "completed",
                       lifetime: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        toks = self.generated_tokens()
        manifest = {
            "schema_version": schema.SCHEMA_VERSION,
            "kind": "serve_run_manifest",
            "run_id": self.run_id,
            "created_unix": time.time(),
            "arch": arch,
            "engine": engine,
            "checkpoint": checkpoint,
            "workload": {
                "requests": len(self.timelines),
                "prompt_tokens": sum(tl.prompt_len for tl in self.timelines.values()),
                "generated_tokens": toks,
            },
            "latency_s": self.latency_summary(),
            "throughput": {
                "tokens_per_s": toks / wall_s if wall_s > 0 else 0.0,
                "wall_s": wall_s,
                "steps": self.steps,
                "prefills": self.prefills,
                "prefill_batches": self.prefill_batches,
                "prefill_chunks": self.chunks,
                "retraces": self.retraces,
            },
            "artifacts": {"log": self.log_path or None},
            "status": status,
        }
        if lifetime is not None:
            # load_effective_params' report: age, GDC state, drift scales
            manifest["lifetime"] = lifetime
        schema.validate_manifest(manifest)
        return manifest

    def write_manifest(self, path: str, **kw) -> Dict[str, Any]:
        manifest = self.build_manifest(**kw)
        with open(path, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.write("\n")
        return manifest

    def close(self) -> None:
        if self._own_sink is not None:
            self._own_sink.close()
            self._own_sink = None
