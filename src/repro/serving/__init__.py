"""Continuous-batching analog serving engine.

Layers (each independently testable):
  kv_pages   — fixed-size KV page accounting: PageAllocator (alloc/free per
               request, leak/double-free checked) + page-table index math.
  scheduler  — per-step admission of waiting prefills into freed decode
               lanes (FIFO, head-of-line page budgeting, no starvation).
  sampling   — sample_greedy + FeedBuilder shared by every serve driver.
  telemetry  — per-request TTFT/TPOT, p50/p99 percentiles, structured JSON
               logging and the shutdown run-artifact manifest.
  schema     — checked-in schemas for log lines + manifest, dependency-free
               validator.
  engine     — ServeEngine: drives prefill/decode disaggregation over the
               paged caches in models/lm.py and restores analog checkpoints
               through the elastic re-key path.
"""
from .engine import EngineConfig, ServeEngine, ServeRequest, load_effective_params  # noqa: F401
from .kv_pages import PageAllocator, needed_pages  # noqa: F401
from .sampling import FeedBuilder, lane_keys, sample_greedy, sample_topk  # noqa: F401
from .scheduler import ContinuousScheduler  # noqa: F401
from .telemetry import Telemetry  # noqa: F401
