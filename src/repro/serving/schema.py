"""Checked-in schemas for the serving engine's observability contracts.

Two artifacts are schema-bound:
  * every JSON log line the engine emits (``LOG_ENVELOPE_SCHEMA`` plus a
    per-event schema in ``EVENT_SCHEMAS``), and
  * the run-artifact manifest written at shutdown (``MANIFEST_SCHEMA``).

``validate`` is a dependency-free validator for the JSON-Schema subset the
contracts use (type / required / properties / additionalProperties / items /
enum / const / minimum) — CI does not install ``jsonschema``, and the tests
must be able to reject drift, not just parse.
"""
from __future__ import annotations

from typing import Any, Dict

SCHEMA_VERSION = 1


class SchemaError(ValueError):
    pass


_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


def _type_ok(value, tname: str) -> bool:
    if tname == "integer":
        return isinstance(value, int) and not isinstance(value, bool)
    if tname == "number":
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    return isinstance(value, _TYPES[tname])


def validate(instance, schema: Dict[str, Any], path: str = "$") -> None:
    """Raise SchemaError where ``instance`` violates ``schema``."""
    if "const" in schema and instance != schema["const"]:
        raise SchemaError(f"{path}: {instance!r} != const {schema['const']!r}")
    if "enum" in schema and instance not in schema["enum"]:
        raise SchemaError(f"{path}: {instance!r} not in {schema['enum']}")
    if "type" in schema:
        types = schema["type"] if isinstance(schema["type"], list) else [schema["type"]]
        if not any(_type_ok(instance, t) for t in types):
            raise SchemaError(f"{path}: {type(instance).__name__} is not {schema['type']}")
    if isinstance(instance, (int, float)) and not isinstance(instance, bool):
        if "minimum" in schema and instance < schema["minimum"]:
            raise SchemaError(f"{path}: {instance} < minimum {schema['minimum']}")
    if isinstance(instance, dict):
        for key in schema.get("required", ()):
            if key not in instance:
                raise SchemaError(f"{path}: missing required key {key!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties", True)
        for key, value in instance.items():
            if key in props:
                validate(value, props[key], f"{path}.{key}")
            elif extra is False:
                raise SchemaError(f"{path}: unexpected key {key!r}")
            elif isinstance(extra, dict):
                validate(value, extra, f"{path}.{key}")
    if isinstance(instance, list) and "items" in schema:
        for i, item in enumerate(instance):
            validate(item, schema["items"], f"{path}[{i}]")


# ---------------------------------------------------------------------------
# log lines
# ---------------------------------------------------------------------------

_nonneg_number = {"type": "number", "minimum": 0}
_nonneg_int = {"type": "integer", "minimum": 0}
_req_id = {"type": "string"}

LOG_EVENTS = ("request_submitted", "request_admitted", "request_finished",
              "engine_stats", "run_summary", "prefill_batch")

LOG_ENVELOPE_SCHEMA = {
    "type": "object",
    "required": ["ts", "event"],
    "properties": {
        "ts": _nonneg_number,                       # seconds, monotonic origin
        "event": {"enum": list(LOG_EVENTS)},
    },
}

EVENT_SCHEMAS: Dict[str, Dict[str, Any]] = {
    "request_submitted": {
        "type": "object", "additionalProperties": False,
        "required": ["ts", "event", "request_id", "prompt_len",
                     "max_new_tokens", "arrival_step"],
        "properties": {
            "ts": _nonneg_number, "event": {"const": "request_submitted"},
            "request_id": _req_id, "prompt_len": _nonneg_int,
            "max_new_tokens": {"type": "integer", "minimum": 1},
            "arrival_step": _nonneg_int,
        },
    },
    "request_admitted": {
        "type": "object", "additionalProperties": False,
        "required": ["ts", "event", "request_id", "lane", "n_pages", "step"],
        "properties": {
            "ts": _nonneg_number, "event": {"const": "request_admitted"},
            "request_id": _req_id, "lane": _nonneg_int,
            # n_pages may be 0 when the whole footprint is prefix-shared
            "n_pages": _nonneg_int, "step": _nonneg_int,
            # optional (absent pre-PR9): CoW prefix sharing + chunked prefill
            "shared_pages": _nonneg_int,
            "chunks": {"type": "integer", "minimum": 1},
        },
    },
    "prefill_batch": {
        "type": "object", "additionalProperties": False,
        "required": ["ts", "event", "step", "bucket", "batch"],
        "properties": {
            "ts": _nonneg_number, "event": {"const": "prefill_batch"},
            "step": _nonneg_int,
            "bucket": {"type": "integer", "minimum": 1},   # padded chunk len
            "batch": {"type": "integer", "minimum": 1},    # real rows in call
        },
    },
    "request_finished": {
        "type": "object", "additionalProperties": False,
        "required": ["ts", "event", "request_id", "lane", "n_tokens",
                     "ttft_s", "tpot_s", "e2e_s", "step"],
        "properties": {
            "ts": _nonneg_number, "event": {"const": "request_finished"},
            "request_id": _req_id, "lane": _nonneg_int,
            "n_tokens": {"type": "integer", "minimum": 1},
            "ttft_s": _nonneg_number, "tpot_s": _nonneg_number,
            "e2e_s": _nonneg_number, "step": _nonneg_int,
        },
    },
    "engine_stats": {
        "type": "object", "additionalProperties": False,
        "required": ["ts", "event", "step", "active_lanes", "waiting",
                     "free_pages"],
        "properties": {
            "ts": _nonneg_number, "event": {"const": "engine_stats"},
            "step": _nonneg_int, "active_lanes": _nonneg_int,
            "waiting": _nonneg_int, "free_pages": _nonneg_int,
        },
    },
    "run_summary": {
        "type": "object", "additionalProperties": False,
        "required": ["ts", "event", "requests", "generated_tokens",
                     "wall_s", "tokens_per_s"],
        "properties": {
            "ts": _nonneg_number, "event": {"const": "run_summary"},
            "requests": _nonneg_int, "generated_tokens": _nonneg_int,
            "wall_s": _nonneg_number, "tokens_per_s": _nonneg_number,
            # optional engine extras (absent from standalone telemetry runs)
            "prefill_batches": _nonneg_int, "prefill_chunks": _nonneg_int,
            "retraces": _nonneg_int, "prefix_hit_rate": _nonneg_number,
        },
    },
}


def validate_log_line(line: Dict[str, Any]) -> None:
    validate(line, LOG_ENVELOPE_SCHEMA)
    validate(line, EVENT_SCHEMAS[line["event"]])


# ---------------------------------------------------------------------------
# run-artifact manifest
# ---------------------------------------------------------------------------

_latency_block = {
    "type": "object", "additionalProperties": False,
    "required": ["p50", "p99", "mean", "max"],
    "properties": {k: _nonneg_number for k in ("p50", "p99", "mean", "max")},
}

MANIFEST_SCHEMA = {
    "type": "object",
    "additionalProperties": False,
    "required": ["schema_version", "kind", "run_id", "created_unix", "arch",
                 "engine", "checkpoint", "workload", "latency_s",
                 "throughput", "artifacts", "status"],
    "properties": {
        "schema_version": {"const": SCHEMA_VERSION},
        "kind": {"const": "serve_run_manifest"},
        "run_id": {"type": "string"},
        "created_unix": _nonneg_number,
        "arch": {"type": "string"},
        "engine": {
            "type": "object", "additionalProperties": False,
            "required": ["mode", "lanes", "page_size", "num_pages",
                         "table_width"],
            "properties": {
                "mode": {"enum": ["continuous", "fixed"]},
                "lanes": {"type": "integer", "minimum": 1},
                "page_size": {"type": "integer", "minimum": 1},
                "num_pages": {"type": "integer", "minimum": 2},
                "table_width": {"type": "integer", "minimum": 1},
                # optional (absent pre-PR9): prefill-path feature toggles
                "prefill_chunk": _nonneg_int,
                "prefill_budget": _nonneg_int,
                "prefix_share": {"type": "boolean"},
                "temperature": _nonneg_number,
                "top_k": _nonneg_int,
            },
        },
        "checkpoint": {
            "type": "object", "additionalProperties": False,
            "required": ["restored", "dir", "algorithm"],
            "properties": {
                "restored": {"type": "boolean"},
                "dir": {"type": "string"},
                "algorithm": {"type": "string"},
            },
        },
        "workload": {
            "type": "object", "additionalProperties": False,
            "required": ["requests", "prompt_tokens", "generated_tokens"],
            "properties": {
                "requests": _nonneg_int, "prompt_tokens": _nonneg_int,
                "generated_tokens": _nonneg_int,
            },
        },
        "latency_s": {
            "type": "object", "additionalProperties": False,
            "required": ["ttft", "tpot", "e2e"],
            # "gap" (optional, absent pre-PR9): pooled inter-token intervals
            # across all requests — the jitter metric chunked prefill targets
            "properties": {"ttft": _latency_block, "tpot": _latency_block,
                           "e2e": _latency_block, "gap": _latency_block},
        },
        "throughput": {
            "type": "object", "additionalProperties": False,
            "required": ["tokens_per_s", "wall_s", "steps", "prefills"],
            "properties": {
                "tokens_per_s": _nonneg_number, "wall_s": _nonneg_number,
                "steps": _nonneg_int, "prefills": _nonneg_int,
                # optional prefill-path counters (absent pre-PR9)
                "prefill_batches": _nonneg_int, "prefill_chunks": _nonneg_int,
                "retraces": _nonneg_int,
            },
        },
        "artifacts": {
            "type": "object", "additionalProperties": False,
            "required": ["log"],
            "properties": {"log": {"type": ["string", "null"]}},
        },
        "status": {"enum": ["completed", "aborted"]},
        # optional (absent pre-lifetime): present only when the served
        # weights went through repro.lifetime (aged and/or GDC-corrected)
        "lifetime": {
            "type": "object", "additionalProperties": False,
            "required": ["age_s", "gdc", "t0_signature", "drift_scale"],
            "properties": {
                "age_s": _nonneg_number,
                "gdc": {"type": "boolean"},
                # where the t0 reference came from: stored by the training
                # driver, recomputed from an unaged restore, or GDC off
                "t0_signature": {"enum": ["checkpoint", "recomputed", "none"]},
                # per-scan-class summary of the per-matrix GDC scales
                "drift_scale": {
                    "type": "object",
                    "additionalProperties": {
                        "type": "object", "additionalProperties": False,
                        "required": ["min", "mean", "max"],
                        "properties": {
                            "min": _nonneg_number,
                            "mean": _nonneg_number,
                            "max": _nonneg_number,
                        },
                    },
                },
            },
        },
    },
}


def validate_manifest(manifest: Dict[str, Any]) -> None:
    validate(manifest, MANIFEST_SCHEMA)
