"""Fixed-size KV page accounting for the continuous-batching engine.

The physical pools live in the model's paged decode cache
(``models/lm.py:init_paged_cache``): per attention layer, ``num_pages`` pages
of ``page_size`` token slots, shared by all lanes.  This module owns the
host-side bookkeeping: which pages belong to which request, and the index
math that turns a page-table row into flat pool slots (the same formula the
jitted gather/scatter in ``models/attention.py`` uses).

Page 0 is reserved as a scratch page: free decode lanes point their whole
table row at it so their (masked-out) writes never touch live pages.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

SCRATCH_PAGE = 0


def needed_pages(total_tokens: int, page_size: int) -> int:
    """Pages a request occupying ``total_tokens`` slots (prompt + generated)
    needs; the engine allocates them all at admission (eager allocation)."""
    return -(-total_tokens // page_size)


def flat_slots(table_row: List[int], page_size: int, length: int) -> List[int]:
    """Flat physical pool slot of logical positions 0..length-1 — the pure
    reference for the jitted index math (used by tests)."""
    return [table_row[j // page_size] * page_size + j % page_size
            for j in range(length)]


class PageAllocator:
    """Free-list page allocator with leak / double-free checking.

    ``alloc`` is all-or-nothing: a request that does not fit leaves the free
    list untouched (the scheduler then blocks admission rather than holding
    a partial allocation).  ``free`` rejects pages that are not currently
    allocated to the given owner, so double-frees and cross-request frees
    fail loudly instead of corrupting the pool.
    """

    def __init__(self, num_pages: int, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages={num_pages} must exceed reserved={reserved}")
        self.num_pages = num_pages
        self.reserved = reserved
        self._free: Deque[int] = deque(range(reserved, num_pages))
        self._owner: Dict[int, object] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        return self.num_pages - self.reserved

    def alloc(self, n: int, owner: object) -> Optional[List[int]]:
        """Allocate ``n`` pages for ``owner``; None (and no change) if the
        pool cannot satisfy the request."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: List[int], owner: object) -> None:
        for p in pages:
            if self._owner.get(p) is not owner:
                raise ValueError(
                    f"page {p} not allocated to {owner!r} (double free or "
                    f"cross-request free)")
        for p in pages:
            del self._owner[p]
            self._free.append(p)

    def check_consistent(self) -> None:
        """Invariant: every page is exactly free or allocated, never both."""
        free = set(self._free)
        allocated = set(self._owner)
        assert len(free) == len(self._free), "duplicate pages on the free list"
        assert not (free & allocated), f"pages both free and allocated: {free & allocated}"
        universe = set(range(self.reserved, self.num_pages))
        assert free | allocated == universe, "leaked pages"
