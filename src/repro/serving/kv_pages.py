"""Fixed-size KV page accounting for the continuous-batching engine.

The physical pools live in the model's paged decode cache
(``models/lm.py:init_paged_cache``): per attention layer, ``num_pages`` pages
of ``page_size`` token slots, shared by all lanes.  This module owns the
host-side bookkeeping: which pages belong to which request, and the index
math that turns a page-table row into flat pool slots (the same formula the
jitted gather/scatter in ``models/attention.py`` uses).

Page 0 is reserved as a scratch page: free decode lanes point their whole
table row at it so their (masked-out) writes never touch live pages.

Copy-on-write prefix sharing: pages are *refcounted* (one owner entry per
holder).  A request whose leading full prompt pages hash-hit the
``PrefixCache`` maps those table-row entries at the shared physical pages
read-only (``share`` adds a ref) and only prefills the unshared tail;
``release`` drops one ref and returns the page to the free list at zero.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Tuple

SCRATCH_PAGE = 0


def needed_pages(total_tokens: int, page_size: int) -> int:
    """Pages a request occupying ``total_tokens`` slots (prompt + generated)
    needs; the engine allocates them all at admission (eager allocation)."""
    return -(-total_tokens // page_size)


def flat_slots(table_row: List[int], page_size: int, length: int) -> List[int]:
    """Flat physical pool slot of logical positions 0..length-1 — the pure
    reference for the jitted index math (used by tests)."""
    return [table_row[j // page_size] * page_size + j % page_size
            for j in range(length)]


class PageAllocator:
    """Refcounted free-list page allocator with leak / double-free checking.

    ``alloc`` is all-or-nothing: a request that does not fit leaves the free
    list untouched (the scheduler then blocks admission rather than holding
    a partial allocation).  Every page carries a list of owner refs:
    ``alloc`` creates the first ref, ``share`` adds one (copy-on-write
    prefix sharing), and ``release``/``free`` drops one — the page returns
    to the free list only when the last ref goes.  Releasing a page the
    given owner does not hold fails loudly (double free / cross-request
    free) instead of corrupting the pool.
    """

    def __init__(self, num_pages: int, reserved: int = 1):
        if num_pages <= reserved:
            raise ValueError(f"num_pages={num_pages} must exceed reserved={reserved}")
        self.num_pages = num_pages
        self.reserved = reserved
        self._free: Deque[int] = deque(range(reserved, num_pages))
        self._owners: Dict[int, List[object]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        return self.num_pages - self.reserved

    def refcount(self, page: int) -> int:
        return len(self._owners.get(page, ()))

    def alloc(self, n: int, owner: object) -> Optional[List[int]]:
        """Allocate ``n`` pages for ``owner``; None (and no change) if the
        pool cannot satisfy the request."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._owners[p] = [owner]
        return pages

    def share(self, pages: List[int], owner: object) -> None:
        """Add a ref to already-allocated pages (prefix sharing): ``owner``
        maps them read-only; the pages outlive every individual holder."""
        for p in pages:
            if p not in self._owners:
                raise ValueError(f"page {p} is free; cannot share")
        for p in pages:
            self._owners[p].append(owner)

    def release(self, pages: List[int], owner: object) -> None:
        """Drop one of ``owner``'s refs per page; free pages at refcount 0.
        Checks *all* pages before mutating so a bad batch changes nothing."""
        for p in pages:
            owners = self._owners.get(p)
            if owners is None or owner not in owners:
                raise ValueError(
                    f"page {p} not allocated to {owner!r} (double free or "
                    f"cross-request free)")
        for p in pages:
            owners = self._owners[p]
            owners.remove(owner)
            if not owners:
                del self._owners[p]
                self._free.append(p)

    # historical name — single-ref release (kept for callers/tests predating
    # refcounts; identical semantics now that a ref is one owner entry)
    free = release

    def check_consistent(self) -> None:
        """Invariant: every page is exactly free or allocated (refcount >= 1),
        never both."""
        free = set(self._free)
        allocated = set(self._owners)
        assert len(free) == len(self._free), "duplicate pages on the free list"
        assert not (free & allocated), f"pages both free and allocated: {free & allocated}"
        universe = set(range(self.reserved, self.num_pages))
        assert free | allocated == universe, "leaked pages"
        for p, owners in self._owners.items():
            assert len(owners) >= 1, f"page {p} allocated with zero refs"


# ---------------------------------------------------------------------------
# prefix cache (copy-on-write prompt-prefix sharing)
# ---------------------------------------------------------------------------


def _page_keys(prompt, page_size: int, n_pages: int) -> List[bytes]:
    """Chained digest per full prompt page: key_i commits to tokens
    [0, (i+1)*page_size), so equal keys mean equal *prefixes*, not just
    equal pages."""
    import numpy as np

    keys = []
    h = b""
    for i in range(n_pages):
        chunk = np.ascontiguousarray(
            np.asarray(prompt[i * page_size:(i + 1) * page_size], np.int32))
        h = hashlib.sha1(h + chunk.tobytes()).digest()
        keys.append(h)
    return keys


class PrefixCache:
    """Maps chained page-content digests to physical pages so admissions with
    a common prompt prefix reuse (refcounted, read-only) committed KV pages.

    The cache holds its own ref on every entry's page, so cached pages
    survive their publisher finishing.  Eviction is LRU over chain *roots*:
    an entry never outlives its parent (a child's key chains through the
    parent's, so a child without its parent could never be probed again) —
    evicting an entry cascades to its descendants.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 max_entries: int = 0):
        self.allocator = allocator
        self.page_size = page_size
        self.max_entries = max_entries          # 0 = unbounded (evict on demand)
        # key -> (page, parent_key | None); OrderedDict keeps LRU order
        self._entries: "OrderedDict[bytes, Tuple[int, Optional[bytes]]]" = OrderedDict()
        self._children: Dict[bytes, set] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    # -------------------------------------------------------------- probe
    def probe(self, prompt, max_pages: int) -> List[int]:
        """Longest run of leading full prompt pages present in the cache
        (up to ``max_pages``).  Touches hit entries for LRU."""
        pages: List[int] = []
        for key in _page_keys(prompt, self.page_size, max_pages):
            entry = self._entries.get(key)
            if entry is None:
                break
            self._entries.move_to_end(key)
            pages.append(entry[0])
        self.hits += len(pages)
        self.misses += max_pages - len(pages)
        return pages

    def acquire(self, prompt, max_pages: int, owner: object) -> List[int]:
        """Probe + take a ref per hit page for ``owner``."""
        pages = self.probe(prompt, max_pages)
        if pages:
            self.allocator.share(pages, owner)
        return pages

    # ------------------------------------------------------------ publish
    def publish(self, prompt, pages: List[int], n_pages: int) -> int:
        """Register ``prompt``'s first ``n_pages`` full pages (physical ids
        ``pages[:n_pages]``).  The cache refs every newly-registered page.
        Returns how many entries were added."""
        added = 0
        parent: Optional[bytes] = None
        for i, key in enumerate(_page_keys(prompt, self.page_size, n_pages)):
            if key in self._entries:
                self._entries.move_to_end(key)
            else:
                self.allocator.share([pages[i]], self)
                self._entries[key] = (pages[i], parent)
                if parent is not None:
                    self._children.setdefault(parent, set()).add(key)
                added += 1
            parent = key
        while self.max_entries and len(self._entries) > self.max_entries:
            if not self.evict_one():
                break
        return added

    # ------------------------------------------------------------- evict
    def _remove(self, key: bytes) -> None:
        page, parent = self._entries.pop(key)
        if parent is not None and parent in self._children:
            self._children[parent].discard(key)
            if not self._children[parent]:
                del self._children[parent]
        for child in sorted(self._children.pop(key, ())):
            if child in self._entries:
                self._remove(child)
        self.allocator.release([page], self)

    def evict_one(self) -> bool:
        """Drop the least-recently-used entry (and its descendants),
        releasing the cache's refs.  Returns False when empty."""
        if not self._entries:
            return False
        key = next(iter(self._entries))
        self._remove(key)
        return True

    def clear(self) -> None:
        while self.evict_one():
            pass

    def check_consistent(self) -> None:
        """Every cached page is allocated with the cache among its owners;
        every child's parent is present."""
        for key, (page, parent) in self._entries.items():
            assert self.allocator.refcount(page) >= 1, f"cached page {page} is free"
            assert parent is None or parent in self._entries, \
                "cache entry outlived its parent"
