"""Continuous-batching scheduler: per-step admission into freed decode lanes.

State machine per request:

  WAITING --admit--> PREFILL --first token--> DECODE --last token--> FINISHED
                (lane + pages assigned)                (lane + pages freed)

Admission policy is strict FIFO with head-of-line page budgeting: each step,
free lanes admit the *oldest* waiting requests whose full page need (prompt +
max_new_tokens, eager allocation) fits the pool.  If the oldest waiting
request does not fit, admission stops — younger, smaller requests do NOT skip
ahead, so no request starves behind a stream of small ones.

With a ``PrefixCache`` attached, admission first maps the request's leading
full prompt pages at cached shared pages (refcounted, read-only) and only
allocates fresh pages for the unshared tail — shared prefixes raise the
pool's effective concurrency, and the page budget accounts for that (a
request the shared pool can hold is admissible even when its full footprint
is not).  Under pressure, cache-only pages are evicted LRU to make room.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .kv_pages import PageAllocator, PrefixCache, SCRATCH_PAGE, needed_pages

WAITING, PREFILL, DECODE, FINISHED = "waiting", "prefill", "decode", "finished"


@dataclasses.dataclass
class ServeRequest:
    """One serving request: a prompt and a generation budget."""
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    arrival_step: int = 0
    seed: int = 0                       # per-request sampling seed (non-greedy)

    # filled in by the scheduler/engine
    state: str = WAITING
    lane: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    shared_pages: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_seq: int = -1
    admitted_step: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.max_new_tokens

    def clone(self) -> "ServeRequest":
        """Fresh copy without scheduler/engine state, so one workload can be
        replayed through several engines."""
        return ServeRequest(self.request_id, self.prompt,
                            self.max_new_tokens, self.arrival_step,
                            seed=self.seed)


@dataclasses.dataclass
class Admission:
    request: ServeRequest
    lane: int
    pages: List[int]                    # freshly allocated (owned) pages
    shared_pages: List[int] = dataclasses.field(default_factory=list)


def max_shared_pages(prompt_len: int, page_size: int) -> int:
    """Full prompt pages a request may map shared: the page holding the last
    prompt token stays private (its hidden state seeds the first sampled
    token, and decode may keep writing into that page)."""
    return max(0, (prompt_len - 1) // page_size)


class ContinuousScheduler:
    """Maps waiting requests onto ``lanes`` decode lanes and a shared page
    pool.  Pure host-side logic — the engine owns the jitted compute."""

    def __init__(self, lanes: int, allocator: PageAllocator, page_size: int,
                 table_width: int, prefix_cache: Optional[PrefixCache] = None):
        self.lanes = lanes
        self.allocator = allocator
        self.page_size = page_size
        self.table_width = table_width
        self.prefix_cache = prefix_cache
        self._free_lanes: Deque[int] = deque(range(lanes))
        self._waiting: Deque[ServeRequest] = deque()
        self._active: Dict[int, ServeRequest] = {}
        self._seq = itertools.count()

    # ------------------------------------------------------------- submit
    def submit(self, req: ServeRequest) -> None:
        npages = needed_pages(req.total_tokens, self.page_size)
        if npages > self.table_width:
            raise ValueError(
                f"request {req.request_id}: {req.total_tokens} tokens need "
                f"{npages} pages > table width {self.table_width}")
        shared = 0
        if self.prefix_cache is not None:
            shared = len(self.prefix_cache.probe(
                req.prompt, max_shared_pages(req.prompt_len, self.page_size)))
        if npages - shared > self.allocator.capacity:
            raise ValueError(
                f"request {req.request_id}: needs {npages} pages "
                f"({shared} prefix-shared), pool has {self.allocator.capacity}")
        req.state = WAITING
        req.submit_seq = next(self._seq)
        self._waiting.append(req)

    # -------------------------------------------------------------- admit
    def _alloc_with_eviction(self, n: int, owner: object) -> Optional[List[int]]:
        """All-or-nothing alloc; under pressure, evict LRU prefix-cache
        entries (freeing pages no active request still refs) and retry."""
        pages = self.allocator.alloc(n, owner)
        while pages is None and self.prefix_cache is not None and len(self.prefix_cache):
            if not self.prefix_cache.evict_one():
                break
            pages = self.allocator.alloc(n, owner)
        return pages

    def admit(self, step: int, limit: Optional[int] = None) -> List[Admission]:
        """Admit the oldest waiting arrived requests into free lanes, while
        pages last.  Head-of-line blocking keeps FIFO order.  ``limit`` caps
        admissions this step (the engine's per-step prefill token budget)."""
        out: List[Admission] = []
        while self._free_lanes and self._waiting:
            if limit is not None and len(out) >= limit:
                break
            head = self._waiting[0]
            if head.arrival_step > step:
                break
            shared: List[int] = []
            if self.prefix_cache is not None:
                shared = self.prefix_cache.acquire(
                    head.prompt,
                    max_shared_pages(head.prompt_len, self.page_size), head)
            n_own = needed_pages(head.total_tokens, self.page_size) - len(shared)
            pages = self._alloc_with_eviction(n_own, head)
            if pages is None:
                if shared:
                    self.allocator.release(shared, head)
                break
            self._waiting.popleft()
            lane = self._free_lanes.popleft()
            head.state, head.lane = PREFILL, lane
            head.pages, head.shared_pages = pages, shared
            head.admitted_step = step
            self._active[lane] = head
            out.append(Admission(head, lane, pages, shared))
        return out

    # ----------------------------------------------------------- publish
    def publish_prefix(self, req: ServeRequest) -> int:
        """Register the request's full prompt pages in the prefix cache once
        their KV is committed (post-prefill).  No-op without a cache."""
        if self.prefix_cache is None:
            return 0
        n_full = req.prompt_len // self.page_size
        row = req.shared_pages + req.pages
        return self.prefix_cache.publish(req.prompt, row, n_full)

    # ------------------------------------------------------------ release
    def release(self, lane: int) -> ServeRequest:
        """Finish the request on ``lane``: drop its page refs (shared pages
        survive in other holders / the cache), return the lane to the free
        pool (it admits the oldest waiting prefill next step)."""
        req = self._active.pop(lane)
        self.allocator.release(req.pages, req)
        if req.shared_pages:
            self.allocator.release(req.shared_pages, req)
        req.state, req.lane = FINISHED, -1
        req.pages, req.shared_pages = [], []
        self._free_lanes.append(lane)
        return req

    # ------------------------------------------------------------ queries
    def active(self) -> Dict[int, ServeRequest]:
        return dict(self._active)

    def request_on(self, lane: int) -> Optional[ServeRequest]:
        return self._active.get(lane)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    @property
    def n_active(self) -> int:
        return len(self._active)

    def has_work(self) -> bool:
        return bool(self._waiting or self._active)

    def table_row(self, req: ServeRequest) -> np.ndarray:
        """The lane's page-table row: shared prefix pages first (they hold
        the leading prompt positions), then owned pages, scratch-padded to
        the fixed table width (unallocated slots are never gathered past the
        request's own positions)."""
        row = np.full((self.table_width,), SCRATCH_PAGE, np.int32)
        pages = req.shared_pages + req.pages
        row[:len(pages)] = np.asarray(pages, np.int32)
        return row
