"""Continuous-batching scheduler: per-step admission into freed decode lanes.

State machine per request:

  WAITING --admit--> PREFILL --first token--> DECODE --last token--> FINISHED
                (lane + pages assigned)                (lane + pages freed)

Admission policy is strict FIFO with head-of-line page budgeting: each step,
free lanes admit the *oldest* waiting requests whose full page need (prompt +
max_new_tokens, eager allocation) fits the pool.  If the oldest waiting
request does not fit, admission stops — younger, smaller requests do NOT skip
ahead, so no request starves behind a stream of small ones.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from .kv_pages import PageAllocator, SCRATCH_PAGE, needed_pages

WAITING, PREFILL, DECODE, FINISHED = "waiting", "prefill", "decode", "finished"


@dataclasses.dataclass
class ServeRequest:
    """One serving request: a prompt and a generation budget."""
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int
    arrival_step: int = 0

    # filled in by the scheduler/engine
    state: str = WAITING
    lane: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    submit_seq: int = -1
    admitted_step: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.max_new_tokens

    def clone(self) -> "ServeRequest":
        """Fresh copy without scheduler/engine state, so one workload can be
        replayed through several engines."""
        return ServeRequest(self.request_id, self.prompt,
                            self.max_new_tokens, self.arrival_step)


@dataclasses.dataclass
class Admission:
    request: ServeRequest
    lane: int
    pages: List[int]


class ContinuousScheduler:
    """Maps waiting requests onto ``lanes`` decode lanes and a shared page
    pool.  Pure host-side logic — the engine owns the jitted compute."""

    def __init__(self, lanes: int, allocator: PageAllocator, page_size: int,
                 table_width: int):
        self.lanes = lanes
        self.allocator = allocator
        self.page_size = page_size
        self.table_width = table_width
        self._free_lanes: Deque[int] = deque(range(lanes))
        self._waiting: Deque[ServeRequest] = deque()
        self._active: Dict[int, ServeRequest] = {}
        self._seq = itertools.count()

    # ------------------------------------------------------------- submit
    def submit(self, req: ServeRequest) -> None:
        npages = needed_pages(req.total_tokens, self.page_size)
        if npages > self.table_width:
            raise ValueError(
                f"request {req.request_id}: {req.total_tokens} tokens need "
                f"{npages} pages > table width {self.table_width}")
        if npages > self.allocator.capacity:
            raise ValueError(
                f"request {req.request_id}: needs {npages} pages, pool has "
                f"{self.allocator.capacity}")
        req.state = WAITING
        req.submit_seq = next(self._seq)
        self._waiting.append(req)

    # -------------------------------------------------------------- admit
    def admit(self, step: int) -> List[Admission]:
        """Admit the oldest waiting arrived requests into free lanes, while
        pages last.  Head-of-line blocking keeps FIFO order."""
        out: List[Admission] = []
        while self._free_lanes and self._waiting:
            head = self._waiting[0]
            if head.arrival_step > step:
                break
            pages = self.allocator.alloc(
                needed_pages(head.total_tokens, self.page_size), head)
            if pages is None:
                break
            self._waiting.popleft()
            lane = self._free_lanes.popleft()
            head.state, head.lane, head.pages = PREFILL, lane, pages
            head.admitted_step = step
            self._active[lane] = head
            out.append(Admission(head, lane, pages))
        return out

    # ------------------------------------------------------------ release
    def release(self, lane: int) -> ServeRequest:
        """Finish the request on ``lane``: free its pages, return the lane
        to the free pool (it admits the oldest waiting prefill next step)."""
        req = self._active.pop(lane)
        self.allocator.free(req.pages, req)
        req.state, req.lane, req.pages = FINISHED, -1, []
        self._free_lanes.append(lane)
        return req

    # ------------------------------------------------------------ queries
    def active(self) -> Dict[int, ServeRequest]:
        return dict(self._active)

    def request_on(self, lane: int) -> Optional[ServeRequest]:
        return self._active.get(lane)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    @property
    def n_active(self) -> int:
        return len(self._active)

    def has_work(self) -> bool:
        return bool(self._waiting or self._active)

    def table_row(self, req: ServeRequest) -> np.ndarray:
        """The lane's page-table row: allocated pages first, scratch-padded
        to the fixed table width (unallocated slots are never gathered past
        the request's own positions)."""
        row = np.full((self.table_width,), SCRATCH_PAGE, np.int32)
        row[:len(req.pages)] = np.asarray(req.pages, np.int32)
        return row
