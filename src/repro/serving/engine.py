"""ServeEngine: continuous batching over the paged analog decode caches.

Prefill/decode disaggregation with an overlap-free prefill path: admissions
are grouped into power-of-two length buckets and run through ONE jitted
``prefill_commit_batch`` per bucket per step — a multi-lane masked prefill
that scatters each row's K/V straight into its pages (no intermediate dense
cache, no per-admission dispatch), collapsing retraces from O(#distinct
prompt lengths) to O(log max_len) and admission cost to one call per bucket.
Long prompts are split into ``prefill_chunk``-sized chunks interleaved with
decode steps (each chunk commits its pages and carries recurrent/latent
state forward), bounding the decode stall any single admission can inflict.
With ``prefix_share`` on, admissions whose leading full prompt pages hash-hit
the ``PrefixCache`` map those table-row entries at shared (refcounted,
read-only) pages and only prefill the unshared tail.

Decode runs one jitted ``serve_step_lanes`` per engine step across all
lanes — every lane at its own position, free and mid-chunk lanes pointed at
the scratch page — so a freed lane admits the oldest waiting prefill on the
next step without recompiling or reshaping anything.

The engine serves the *effective* analog weights: ``load_effective_params``
restores a training checkpoint through the elastic re-key path and merges
tile state per-TilePolicy (the paper's deployment story — the arrays that
trained are the arrays that serve).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kv_pages import PageAllocator, PrefixCache, SCRATCH_PAGE, needed_pages
from .sampling import FeedBuilder, lane_keys, sample_greedy, sample_topk
from .scheduler import ContinuousScheduler, DECODE, ServeRequest
from .telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    lanes: int = 8
    page_size: int = 16
    num_pages: int = 128          # shared pool per attention layer (incl. scratch)
    max_len: int = 256            # per-request prompt + generation bound
    stats_every: int = 0          # emit engine_stats every N steps (0 = off)
    log_path: str = ""            # JSON log lines (one object per line)
    manifest_path: str = ""       # run-artifact manifest written at shutdown
    prefill_chunk: int = 0        # split prompts into chunks of this many
                                  # tokens (0 = whole-prompt; page-size multiple)
    prefill_budget: int = 0       # max prefill tokens dispatched per step
                                  # (0 = unlimited) — caps decode jitter when
                                  # many lanes are mid-chunk at once
    prefix_share: bool = False    # CoW prompt-prefix page sharing
    temperature: float = 0.0      # 0 = greedy (the identity-test default)
    top_k: int = 0                # 0 = no top-k filter

    @property
    def table_width(self) -> int:
        return needed_pages(self.max_len, self.page_size)


# the per-deployment lifetime RNG: drift exponents / read noise are frozen
# physical facts of one programmed array, so the key is a constant — two
# loads of the same checkpoint at the same age see the same conductances
_LIFETIME_KEY_SEED = 0xD81F7


def _drift_scale_summary(tiles, scales: Dict[str, float]) -> Dict[str, Dict[str, float]]:
    """Per-scan-class min/mean/max of the per-matrix GDC scales — the
    compact form the serve manifest records."""
    pidx = dict(tiles.index)
    out: Dict[str, Dict[str, float]] = {}
    for cname, gnames in tiles.class_index:
        vals = [scales[p] for g in gnames for p in pidx[g] if p in scales]
        if vals:
            out[cname] = {"min": min(vals), "mean": sum(vals) / len(vals),
                          "max": max(vals)}
    return out


def load_effective_params(model, ckpt_dir: str, algorithm: str, smoke: bool,
                          *, age_s: float = 0.0, gdc: bool = False,
                          with_report: bool = False):
    """Rebuild the training-time plan, restore the checkpoint through the
    (re-keying) elastic restore path, and merge effective analog weights.

    The restore template is built with ``abstract_state`` from
    ``eval_shape``'d params — no throwaway tile/optimizer state is ever
    materialized (at LM scale trainer.init would allocate several times
    the served weights just to be overwritten).

    Lifetime (``repro.lifetime``): ``age_s`` ages every analog leaf to
    ``drift_t0 + age_s`` under its own stack's ``device_w`` preset
    (conductance drift + read noise; ``age_s == 0`` is bit-exact);
    ``gdc=True`` then applies Global Drift Compensation against the t0
    signatures stored in the checkpoint manifest (recomputed from the
    unaged restore when the checkpoint predates them). With
    ``with_report=True`` returns ``(params, report)`` where ``report`` is
    the manifest-shaped lifetime block."""
    from repro.checkpoint import ckpt
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig, merge_effective
    from repro.launch.train import make_plan

    plan = make_plan(algorithm, smoke)
    trainer = AnalogTrainer(
        model.loss,
        TrainerConfig(digital=DigitalOptConfig(kind="sgdm"),
                      schedule=ScheduleConfig(kind="constant", base_lr=0.0)),
        plan=plan)
    aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    template = trainer.abstract_state(aparams)
    state = ckpt.restore(template, ckpt_dir)
    print(f"[serve] restored step {int(np.asarray(state['step']))} from "
          f"{ckpt_dir} | {trainer.describe_plan(aparams)}", flush=True)
    params = merge_effective(state["params"], state["tiles"], trainer.cfg.tile)
    report: Dict[str, Any] = {"age_s": float(age_s), "gdc": bool(gdc),
                              "t0_signature": "none", "drift_scale": {}}
    if age_s > 0.0 or gdc:
        from repro.lifetime import drift as ldrift
        from repro.lifetime import gdc as lgdc

        tiles = state["tiles"]
        cfg_map = ldrift.lifetime_cfg_map(params, tiles,
                                          trainer.cfg.tile.device_w)
        sig0 = None
        if gdc:
            manifest = ckpt.read_manifest(ckpt_dir)
            sig0 = manifest.get("gdc_signatures")
            report["t0_signature"] = "checkpoint"
            if sig0:
                sig0 = {p: v for p, v in sig0.items() if p in cfg_map}
            if not sig0:
                # pre-lifetime checkpoint: the unaged restore IS the t0
                # state, so its signatures are the reference
                report["t0_signature"] = "recomputed"
                sig_fn = jax.jit(lambda t: lgdc.signature_tree(
                    t, tuple(sorted(cfg_map))))
                sig0 = {p: float(v) for p, v in sig_fn(params).items()}
        if age_s > 0.0:
            params = ldrift.age_params(
                params, cfg_map, age_s,
                jax.random.PRNGKey(_LIFETIME_KEY_SEED))
        if gdc:
            params, scales = lgdc.correct_params(params, sig0)
            report["drift_scale"] = _drift_scale_summary(tiles, scales)
    if with_report:
        return params, report
    return params


def _pow2_ceil(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


@dataclasses.dataclass
class _Segment:
    """One prefill work item: ``req``'s prompt tokens [start, start+length)
    going to ``lane``.  ``fresh`` marks the request's first segment (zero
    prior recurrent state)."""
    req: ServeRequest
    lane: int
    start: int
    length: int
    fresh: bool

    @property
    def final(self) -> bool:
        return self.start + self.length >= self.req.prompt_len


class ServeEngine:
    def __init__(self, model, params, ecfg: EngineConfig,
                 telemetry: Optional[Telemetry] = None, arch: str = "",
                 checkpoint: Optional[Dict[str, Any]] = None,
                 lifetime: Optional[Dict[str, Any]] = None):
        if model.cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching supports decoder-only models; use the "
                "fixed-batch driver for enc-dec archs")
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.arch = arch or model.cfg.name
        self.checkpoint = checkpoint or {"restored": False, "dir": "", "algorithm": ""}
        self.lifetime = lifetime          # load_effective_params report
        self.telemetry = telemetry or Telemetry(log_path=ecfg.log_path)

        # per-family capability gates (all off -> exact-length fresh batches)
        kinds = set(model.cfg.layer_kinds)
        # padding a rec row would re-associate the RG-LRU associative scan
        self._pad_ok = "rec" not in kinds
        chunk = int(ecfg.prefill_chunk)
        chunk_ok = (chunk > 0 and self._pad_ok
                    and chunk % ecfg.page_size == 0
                    and ("ssm" not in kinds or chunk % model.cfg.ssm_chunk == 0))
        self._chunk = chunk if chunk_ok else 0
        # shared pages only make sense for page-pool layers; MLA latents and
        # recurrent state are per-lane and cannot be mapped read-only
        self._share = bool(ecfg.prefix_share) and kinds <= {"attn", "attn_local"}

        self.allocator = PageAllocator(ecfg.num_pages, reserved=1)
        self.prefix_cache = (PrefixCache(self.allocator, ecfg.page_size)
                             if self._share else None)
        self.scheduler = ContinuousScheduler(
            ecfg.lanes, self.allocator, ecfg.page_size, ecfg.table_width,
            prefix_cache=self.prefix_cache)
        self._feed = FeedBuilder(model.cfg)

        self._paged = model.init_paged_cache(
            ecfg.lanes, ecfg.num_pages, ecfg.page_size, ecfg.max_len)

        # ONE jitted entrypoint serves plain bucketed prefill (start=0),
        # chunk continuation, and prefix-shared tails: the masked multi-lane
        # prefill scatters K/V straight into the rows' pages and samples the
        # last valid position in-graph.  Signatures are (len bucket, batch
        # bucket) pairs — O(log max_len * log lanes) total.
        temp, top_k = float(ecfg.temperature), int(ecfg.top_k)
        T = ecfg.table_width

        def prefill_batch(params, packed, paged, tw):
            # packed (B, Cb+tw+5) int32 — ONE host upload per bucketed call:
            # [chunk tokens | table row | lane | start | length | fresh | seed]
            # ``tw`` (static) is the pow2 page-span bucket: only the table
            # columns the chunk can actually reach ride along, so the paged
            # attention gathers tw*page_size rows instead of the full width
            Cb = packed.shape[1] - tw - 5
            tokens = packed[:, :Cb]
            tables = packed[:, Cb:Cb + tw]
            lanes, starts = packed[:, Cb + tw], packed[:, Cb + tw + 1]
            lengths = packed[:, Cb + tw + 2]
            fresh = packed[:, Cb + tw + 3] != 0
            seeds = packed[:, Cb + tw + 4]
            logits, paged = model.prefill_commit_batch(
                params, tokens, paged, tables, lanes, starts, lengths, fresh)
            if temp > 0.0:
                tok = sample_topk(logits, temp, top_k,
                                  lane_keys(seeds, starts + lengths))
            else:
                tok = sample_greedy(logits)
            return tok, paged

        self._prefill_batch = jax.jit(prefill_batch, static_argnums=(3,),
                                      donate_argnums=(2,))
        self.prefill_signatures: set = set()

        # the decode step advances every lane's position on-device; free
        # (and mid-chunk) lanes drift past their all-scratch table rows,
        # which is harmless — their writes/reads clamp to the scratch page
        # and their outputs are discarded.  Lane state rides in ONE packed
        # (B, T+4) int32 array — [table row | pos | last | seed | live] — so
        # a dirty step re-uploads one host array and steady-state decode
        # donates the returned state (pos+1 and the sampled token are
        # written back in-graph) straight into the next step
        def step_fn(params, cache, state):
            table, pos = state[:, :T], state[:, T]
            last, seeds = state[:, T + 1:T + 2], state[:, T + 2]
            live = state[:, T + 3] != 0
            if temp > 0.0:
                logits, cache = model.decode_step_lanes(params, last, cache,
                                                        table, pos, live)
                toks = sample_topk(logits, temp, top_k,
                                   lane_keys(seeds, pos + 1))
            else:
                toks, cache = model.serve_step_lanes(params, last, cache,
                                                     table, pos, live)
            state = state.at[:, T].add(1).at[:, T + 1].set(toks[:, 0])
            return toks, cache, state

        self._step = jax.jit(step_fn, donate_argnums=(1, 2))

        # host-side lane state, mirrored on device between admissions so
        # steady-state decode re-uses device arrays instead of re-uploading;
        # the named mirrors are views aliasing one packed int32 block
        self._ls = np.zeros((ecfg.lanes, T + 4), np.int32)
        self._ls[:, :T] = SCRATCH_PAGE
        self._table = self._ls[:, :T]
        self._pos = self._ls[:, T]
        self._last = self._ls[:, T + 1:T + 2]
        self._seeds = self._ls[:, T + 2]
        self._live = self._ls[:, T + 3]
        self._dev = None          # packed lane-state device mirror
        self._dirty = True        # lane state changed since last upload
        self._cont: Dict[int, _Segment] = {}   # lane -> next pending chunk

    # ----------------------------------------------------------------- run
    def submit(self, req: ServeRequest) -> None:
        self.scheduler.submit(req)
        self.telemetry.request_submitted(req.request_id, req.prompt_len,
                                         req.max_new_tokens, req.arrival_step)

    def _finish(self, lane: int, step: int) -> None:
        req = self.scheduler.release(lane)
        self.telemetry.request_finished(req.request_id, lane, step)
        self._table[lane] = SCRATCH_PAGE
        self._pos[lane] = 0
        self._last[lane] = 0
        self._seeds[lane] = 0
        self._live[lane] = False
        self._dirty = True

    # ------------------------------------------------------------- prefill
    def _len_bucket(self, n: int) -> int:
        return _pow2_ceil(n) if self._pad_ok else n

    def _segment(self, req: ServeRequest, lane: int, start: int,
                 fresh: bool) -> _Segment:
        remaining = req.prompt_len - start
        seg = min(self._chunk, remaining) if self._chunk else remaining
        return _Segment(req, lane, start, seg, fresh)

    def _gather_segments(self, step: int) -> List[_Segment]:
        """This step's prefill work: pending chunk continuations first (one
        chunk per lane per step), then fresh admissions.  A prefill token
        budget (``ecfg.prefill_budget``) bounds the work batched into one
        step — continuations past it wait, admissions past it defer — so a
        pile-up of mid-chunk lanes cannot stretch every decode interval."""
        # the budget is a chunked-mode knob: segments then have bounded
        # length, so capping tokens per step caps the decode stall
        budget = (self.ecfg.prefill_budget or None) if self._chunk else None
        work: List[_Segment] = []
        for lane in sorted(self._cont):
            seg = self._cont[lane]
            if budget is not None and work and budget < seg.length:
                break
            del self._cont[lane]
            if budget is not None:
                budget -= seg.length
            work.append(seg)
        limit = None
        if budget is not None:
            limit = max(0, budget) // self._chunk
            if not work and limit == 0:
                limit = 1      # keep making progress even on a tiny budget
            if limit == 0:
                return work
        for adm in self.scheduler.admit(step, limit):
            req, lane = adm.request, adm.lane
            n_chunks = (1 if not self._chunk else
                        -(-(req.prompt_len - len(adm.shared_pages)
                            * self.ecfg.page_size) // self._chunk))
            self.telemetry.request_admitted(
                req.request_id, lane, len(adm.pages), step,
                shared_pages=len(adm.shared_pages), chunks=n_chunks)
            start = len(adm.shared_pages) * self.ecfg.page_size
            work.append(self._segment(req, lane, start, True))
        return work

    def _dispatch_group(self, Cb: int, items: List[_Segment], step: int):
        """Pad ``items`` to a power-of-two batch (replicating item 0 — the
        duplicate rows scatter identical values) and run one jitted call."""
        Bb = _pow2_ceil(len(items))
        rows = items + [items[0]] * (Bb - len(items))
        ps, T = self.ecfg.page_size, self.ecfg.table_width
        span = max(-(-(seg.start + seg.length) // ps) for seg in items)
        tw = min(T, _pow2_ceil(span))
        packed = np.zeros((Bb, Cb + tw + 5), np.int32)
        for i, seg in enumerate(rows):
            packed[i, :seg.length] = seg.req.prompt[seg.start:seg.start + seg.length]
            packed[i, Cb:Cb + tw] = self.scheduler.table_row(seg.req)[:tw]
            packed[i, Cb + tw] = seg.lane
            packed[i, Cb + tw + 1] = seg.start
            packed[i, Cb + tw + 2] = seg.length
            packed[i, Cb + tw + 3] = int(seg.fresh)
            packed[i, Cb + tw + 4] = seg.req.seed
        sig = (Cb, Bb, tw)
        if sig not in self.prefill_signatures:
            self.prefill_signatures.add(sig)
            self.telemetry.retraces += 1
        tok, self._paged = self._prefill_batch(
            self.params, jnp.asarray(packed), self._paged, tw)
        self.telemetry.prefill_batches += 1
        self.telemetry.prefill_batch(step, Cb, len(items))
        return tok

    def _admit_and_prefill(self, step: int) -> None:
        work = self._gather_segments(step)
        if not work:
            return
        groups: Dict[int, List[_Segment]] = {}
        for seg in work:
            groups.setdefault(self._len_bucket(seg.length), []).append(seg)
        # dispatch every bucket, then sync tokens once per step
        pending = [(Cb, items, self._dispatch_group(Cb, items, step))
                   for Cb, items in sorted(groups.items())]
        for _, items, tok in pending:
            host = np.asarray(tok)
            for i, seg in enumerate(items):
                self.telemetry.chunks += 1
                req, lane = seg.req, seg.lane
                if not seg.final:
                    self._cont[lane] = self._segment(
                        req, lane, seg.start + seg.length, False)
                    continue
                first = int(host[i, 0])
                req.tokens.append(first)
                req.state = DECODE
                self.telemetry.prefills += 1
                self.telemetry.first_token(req.request_id)
                if self._share:
                    self.scheduler.publish_prefix(req)
                self._table[lane] = self.scheduler.table_row(req)
                self._pos[lane] = req.prompt_len
                self._last[lane, 0] = first
                self._seeds[lane] = req.seed
                self._live[lane] = True
                self._dirty = True
                if len(req.tokens) >= req.max_new_tokens:
                    self._finish(lane, step)

    # -------------------------------------------------------------- decode
    def _decode_once(self, step: int) -> None:
        active = self.scheduler.active()
        decoding = {l: r for l, r in active.items() if r.state == DECODE}
        if not decoding:
            return
        if self._dirty:
            self._dev = jnp.asarray(self._ls)
            self._dirty = False
        toks, self._paged, self._dev = self._step(self.params, self._paged,
                                                  self._dev)
        host_toks = np.asarray(toks)
        self.telemetry.steps += 1
        for lane, req in decoding.items():
            tok = int(host_toks[lane, 0])
            req.tokens.append(tok)
            self.telemetry.token(req.request_id)
            self._pos[lane] += 1
            self._last[lane, 0] = tok
            if len(req.tokens) >= req.max_new_tokens:
                self._finish(lane, step)

    def run(self, requests: List[ServeRequest]) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Serve ``requests`` to completion; returns ({request_id: generated
        tokens}, run summary).  Writes the manifest at shutdown when
        configured."""
        t0 = time.monotonic()
        for req in requests:
            self.submit(req)
        step = 0
        while self.scheduler.has_work():
            self._admit_and_prefill(step)
            self._decode_once(step)
            if self.ecfg.stats_every and step % self.ecfg.stats_every == 0:
                self.telemetry.engine_stats(step, self.scheduler.n_active,
                                            self.scheduler.n_waiting,
                                            self.allocator.free_pages)
            step += 1
        wall = time.monotonic() - t0
        summary = self.telemetry.run_summary(wall, extras=self._run_extras())
        self.shutdown(wall)
        return ({r.request_id: np.asarray(r.tokens, np.int32) for r in requests},
                summary)

    def _run_extras(self) -> Dict[str, Any]:
        ex: Dict[str, Any] = {
            "prefill_batches": self.telemetry.prefill_batches,
            "prefill_chunks": self.telemetry.chunks,
            "retraces": self.telemetry.retraces,
        }
        if self.prefix_cache is not None:
            probes = self.prefix_cache.hits + self.prefix_cache.misses
            ex["prefix_hit_rate"] = (self.prefix_cache.hits / probes
                                     if probes else 0.0)
        return ex

    # ------------------------------------------------------------ shutdown
    def manifest_meta(self) -> Dict[str, Any]:
        e = self.ecfg
        return {"mode": "continuous", "lanes": e.lanes, "page_size": e.page_size,
                "num_pages": e.num_pages, "table_width": e.table_width,
                "prefill_chunk": self._chunk,
                "prefill_budget": int(e.prefill_budget) if self._chunk else 0,
                "prefix_share": self._share,
                "temperature": float(e.temperature), "top_k": int(e.top_k)}

    def shutdown(self, wall_s: float, status: str = "completed") -> Optional[Dict]:
        if self.prefix_cache is not None:
            self.prefix_cache.check_consistent()
        manifest = None
        if self.ecfg.manifest_path:
            manifest = self.telemetry.write_manifest(
                self.ecfg.manifest_path, arch=self.arch,
                engine=self.manifest_meta(), checkpoint=self.checkpoint,
                wall_s=wall_s, status=status, lifetime=self.lifetime)
        self.telemetry.close()
        return manifest
