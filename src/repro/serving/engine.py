"""ServeEngine: continuous batching over the paged analog decode caches.

Prefill/decode disaggregation: prefills run as dedicated batch-1 calls
through the model's dense prefill path (reusing the exact math of the
training-time forward), then hand their KV off to the paged pools via the
gather-free ``commit_prefill`` scatter.  Decode runs one jitted
``serve_step_lanes`` per engine step across all lanes — every lane at its
own position, free lanes pointed at the scratch page — so a freed lane
admits the oldest waiting prefill on the next step without recompiling or
reshaping anything.

The engine serves the *effective* analog weights: ``load_effective_params``
restores a training checkpoint through the elastic re-key path and merges
tile state per-TilePolicy (the paper's deployment story — the arrays that
trained are the arrays that serve).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kv_pages import PageAllocator, SCRATCH_PAGE, needed_pages
from .sampling import FeedBuilder, sample_greedy
from .scheduler import ContinuousScheduler, DECODE, ServeRequest
from .telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    lanes: int = 8
    page_size: int = 16
    num_pages: int = 128          # shared pool per attention layer (incl. scratch)
    max_len: int = 256            # per-request prompt + generation bound
    stats_every: int = 0          # emit engine_stats every N steps (0 = off)
    log_path: str = ""            # JSON log lines (one object per line)
    manifest_path: str = ""       # run-artifact manifest written at shutdown

    @property
    def table_width(self) -> int:
        return needed_pages(self.max_len, self.page_size)


def load_effective_params(model, ckpt_dir: str, algorithm: str, smoke: bool):
    """Rebuild the training-time plan, restore the checkpoint through the
    (re-keying) elastic restore path, and merge effective analog weights.

    The restore template is built with ``abstract_state`` from
    ``eval_shape``'d params — no throwaway tile/optimizer state is ever
    materialized (at LM scale trainer.init would allocate several times
    the served weights just to be overwritten)."""
    from repro.checkpoint import ckpt
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig, merge_effective
    from repro.launch.train import make_plan

    plan = make_plan(algorithm, smoke)
    trainer = AnalogTrainer(
        model.loss,
        TrainerConfig(digital=DigitalOptConfig(kind="sgdm"),
                      schedule=ScheduleConfig(kind="constant", base_lr=0.0)),
        plan=plan)
    aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    template = trainer.abstract_state(aparams)
    state = ckpt.restore(template, ckpt_dir)
    print(f"[serve] restored step {int(np.asarray(state['step']))} from "
          f"{ckpt_dir} | {trainer.describe_plan(aparams)}", flush=True)
    return merge_effective(state["params"], state["tiles"], trainer.cfg.tile)


class ServeEngine:
    def __init__(self, model, params, ecfg: EngineConfig,
                 telemetry: Optional[Telemetry] = None, arch: str = "",
                 checkpoint: Optional[Dict[str, Any]] = None):
        if model.cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching supports decoder-only models; use the "
                "fixed-batch driver for enc-dec archs")
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.arch = arch or model.cfg.name
        self.checkpoint = checkpoint or {"restored": False, "dir": "", "algorithm": ""}
        self.telemetry = telemetry or Telemetry(log_path=ecfg.log_path)

        self.allocator = PageAllocator(ecfg.num_pages, reserved=1)
        self.scheduler = ContinuousScheduler(
            ecfg.lanes, self.allocator, ecfg.page_size, ecfg.table_width)
        self._feed = FeedBuilder(model.cfg)

        self._paged = model.init_paged_cache(
            ecfg.lanes, ecfg.num_pages, ecfg.page_size, ecfg.max_len)

        # one jitted call per admission: the batch-1 dense cache is created
        # *inside* the trace (free zeros, no per-leaf host allocation), the
        # first token is sampled in-graph, and the KV lands in the pages —
        # no intermediate dense cache ever leaves the device
        def prefill_commit(params, feed, paged, row, lane, *, prompt_len,
                           page_size):
            dense = model.init_cache(1, prompt_len)
            logits, dense = model.prefill(params, feed, dense)
            tok = sample_greedy(logits)
            paged = model.commit_prefill(paged, dense, row, lane,
                                         prompt_len=prompt_len,
                                         page_size=page_size)
            return tok, paged

        self._prefill_commit = jax.jit(
            prefill_commit, static_argnames=("prompt_len", "page_size"),
            donate_argnums=(2,))

        # the decode step advances every lane's position on-device; free
        # lanes drift past their (all-scratch) table rows, which is
        # harmless — their writes/reads clamp to the scratch page and their
        # outputs are discarded — and admission rewrites their rows anyway
        def step_fn(params, last, cache, table, pos):
            toks, cache = model.serve_step_lanes(params, last, cache, table,
                                                 pos)
            return toks, cache, pos + 1

        self._step = jax.jit(step_fn, donate_argnums=(2,))

        # host-side lane state, mirrored on device between admissions so
        # steady-state decode re-uses device arrays instead of re-uploading
        T = ecfg.table_width
        self._table = np.full((ecfg.lanes, T), SCRATCH_PAGE, np.int32)
        self._pos = np.zeros((ecfg.lanes,), np.int32)
        self._last = np.zeros((ecfg.lanes, 1), np.int32)
        self._dev = None          # (last, table, pos) device mirrors
        self._dirty = True        # lane state changed since last upload

    # ----------------------------------------------------------------- run
    def submit(self, req: ServeRequest) -> None:
        self.scheduler.submit(req)
        self.telemetry.request_submitted(req.request_id, req.prompt_len,
                                         req.max_new_tokens, req.arrival_step)

    def _finish(self, lane: int, step: int) -> None:
        req = self.scheduler.release(lane)
        self.telemetry.request_finished(req.request_id, lane, step)
        self._table[lane] = SCRATCH_PAGE
        self._pos[lane] = 0
        self._last[lane] = 0
        self._dirty = True

    def _admit_and_prefill(self, step: int) -> None:
        for adm in self.scheduler.admit(step):
            req, lane = adm.request, adm.lane
            self.telemetry.request_admitted(req.request_id, lane,
                                            len(adm.pages), step)
            row = self.scheduler.table_row(req)
            tok, self._paged = self._prefill_commit(
                self.params, self._feed(req.prompt[None]), self._paged,
                jnp.asarray(row), lane, prompt_len=req.prompt_len,
                page_size=self.ecfg.page_size)
            self.telemetry.prefills += 1
            first = int(np.asarray(tok)[0, 0])
            req.tokens.append(first)
            req.state = DECODE
            self.telemetry.first_token(req.request_id)
            self._table[lane] = row
            self._pos[lane] = req.prompt_len
            self._last[lane, 0] = first
            self._dirty = True
            if len(req.tokens) >= req.max_new_tokens:
                self._finish(lane, step)

    def _decode_once(self, step: int) -> None:
        active = self.scheduler.active()
        if not active:
            return
        if self._dirty:
            self._dev = (jnp.asarray(self._last), jnp.asarray(self._table),
                         jnp.asarray(self._pos))
            self._dirty = False
        last, table, pos = self._dev
        toks, self._paged, pos = self._step(self.params, last, self._paged,
                                            table, pos)
        self._dev = (toks, table, pos)
        host_toks = np.asarray(toks)
        self.telemetry.steps += 1
        for lane, req in active.items():
            tok = int(host_toks[lane, 0])
            req.tokens.append(tok)
            self.telemetry.token(req.request_id)
            self._pos[lane] += 1
            self._last[lane, 0] = tok
            if len(req.tokens) >= req.max_new_tokens:
                self._finish(lane, step)

    def run(self, requests: List[ServeRequest]) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Serve ``requests`` to completion; returns ({request_id: generated
        tokens}, run summary).  Writes the manifest at shutdown when
        configured."""
        t0 = time.monotonic()
        for req in requests:
            self.submit(req)
        step = 0
        while self.scheduler.has_work():
            self._admit_and_prefill(step)
            self._decode_once(step)
            if self.ecfg.stats_every and step % self.ecfg.stats_every == 0:
                self.telemetry.engine_stats(step, self.scheduler.n_active,
                                            self.scheduler.n_waiting,
                                            self.allocator.free_pages)
            step += 1
        wall = time.monotonic() - t0
        summary = self.telemetry.run_summary(wall)
        self.shutdown(wall)
        return ({r.request_id: np.asarray(r.tokens, np.int32) for r in requests},
                summary)

    # ------------------------------------------------------------ shutdown
    def manifest_meta(self) -> Dict[str, Any]:
        e = self.ecfg
        return {"mode": "continuous", "lanes": e.lanes, "page_size": e.page_size,
                "num_pages": e.num_pages, "table_width": e.table_width}

    def shutdown(self, wall_s: float, status: str = "completed") -> Optional[Dict]:
        manifest = None
        if self.ecfg.manifest_path:
            manifest = self.telemetry.write_manifest(
                self.ecfg.manifest_path, arch=self.arch,
                engine=self.manifest_meta(), checkpoint=self.checkpoint,
                wall_s=wall_s, status=status)
        self.telemetry.close()
        return manifest
