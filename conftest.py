"""Repo-root conftest: make src/ and benchmarks importable in tests.

NOTE: deliberately does NOT set XLA_FLAGS — smoke tests and benches must see
the single real CPU device; multi-device tests spawn subprocesses.
"""
import os
import sys

ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (os.path.join(ROOT, "src"), ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)
