"""Paper §4 end-to-end: fully-analog FCN trained with E-RIDER vs TT-v2.

Reproduces the Tables 1-2 story at example scale: on low-state devices
(~4 conductance states) with a nonzero symmetric-point reference, the
static-calibration baseline (TT-v2) degrades while E-RIDER dynamically
tracks the SP and trains through it.

Run: PYTHONPATH=src:. python examples/analog_mnist.py
"""
from benchmarks.common import device_pair, train_image_model
from repro.data import ImageDataset


def main():
    data = ImageDataset(n_train=4096, n_test=1024, seed=11)
    dev_p, dev_w = device_pair(dw_min=0.4622, sigma_pm=0.7125,
                               sigma_c2c=0.2174, ref_mean=0.4, ref_std=0.4)
    print("device: ~4 states (dw_min=0.4622), SP reference ~ N(0.4, 0.4^2)\n")
    for algo in ("ttv2", "agad", "erider"):
        res = train_image_model(algorithm=algo, dev_p=dev_p, dev_w=dev_w,
                                epochs=2, data=data, seed=1)
        sp = f"  sp_err={res.sp_err:.4f}" if res.sp_err is not None else ""
        print(f"{algo:8s} test_acc={res.test_acc:.3f}  "
              f"pulses={res.pulses:.2e}  wall={res.wall_s:.0f}s{sp}")


if __name__ == "__main__":
    main()
