"""End-to-end driver: train a reduced LM on a *mixed* AnalogPlan for a few
hundred steps on the synthetic bigram stream, with checkpointing and
fault-tolerance machinery engaged — the same train_step the multi-pod
dry-run lowers at full scale.

The default plan trains attention tiles with RIDER and everything else
with E-RIDER (embeddings/heads stay digital via ``repro.api.lm_plan``),
exercising the heterogeneous-device path: two policy-split tile groups,
each under its own algorithm, in one jitted train_step. Pass a plain
``--algorithm erider`` for the single-policy setup, or any
``pattern=algorithm`` list of your own (see repro/launch/train.py).

Run: PYTHONPATH=src python examples/lm_analog_training.py [--steps 500]
"""
import sys

from repro.launch import train


def main():
    argv = ["--arch", "qwen2-0.5b", "--smoke", "--steps", "200",
            "--batch", "8", "--seq", "64", "--ckpt-dir", "/tmp/repro_lm_ckpt",
            "--ckpt-every", "100", "--log-every", "20",
            "--algorithm", "attn=rider,**=erider"]
    # pass through any user overrides (e.g. --steps 500 --algorithm erider)
    argv.extend(sys.argv[1:])
    train.main(argv)


if __name__ == "__main__":
    main()
