"""End-to-end driver: train a reduced LM with analog E-RIDER tiles for a
few hundred steps on the synthetic bigram stream, with checkpointing and
fault-tolerance machinery engaged — the same train_step the multi-pod
dry-run lowers at full scale.

Run: PYTHONPATH=src python examples/lm_analog_training.py [--steps 200]
"""
import sys

from repro.launch import train


def main():
    argv = ["--arch", "qwen2-0.5b", "--smoke", "--steps", "200",
            "--batch", "8", "--seq", "64", "--ckpt-dir", "/tmp/repro_lm_ckpt",
            "--ckpt-every", "100", "--log-every", "20"]
    # pass through any user overrides (e.g. --steps 500 --arch mamba2-2.7b)
    argv.extend(sys.argv[1:])
    train.main(argv)


if __name__ == "__main__":
    main()
