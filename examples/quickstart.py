"""Quickstart: E-RIDER analog training on a toy problem in ~40 lines.

Shows the user-facing plan API (``repro.api``): device config -> TilePolicy
-> AnalogPlan -> AnalogTrainer over any loss function. The SP-tracking
telemetry (sp_err) demonstrates the paper's contribution live: Q converges
to the device's symmetric point during training, with no pre-training
calibration.

For heterogeneous plans (different devices/algorithms per layer) see
examples/lm_analog_training.py and the AnalogPlan section of the README.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import AnalogPlan, AnalogTrainer, TilePolicy, TrainerConfig
from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.tile import TileConfig

# a noisy least-squares problem: f(W) = 0.5 ||W - W*||^2
W_STAR = jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.05


def loss_fn(params, batch, rng):
    noise = 0.02 * jax.random.normal(rng, params["w"].shape)
    resid = params["w"] - W_STAR
    surrogate = jnp.sum(params["w"] * jax.lax.stop_gradient(resid + noise))
    return surrogate, {"true_loss": 0.5 * jnp.sum(resid ** 2)}


def main():
    # analog devices with a *nonzero, unknown* symmetric point (the paper's
    # hard setting): per-element SP ~ N(0.3, 0.2^2)
    dev_p = DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1,
                         sigma_c2c=0.05, ref_mean=0.3, ref_std=0.2)
    dev_w = DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1,
                         sigma_c2c=0.05)
    policy = TilePolicy(
        TileConfig(algorithm="erider", device_p=dev_p, device_w=dev_w,
                   lr_p=0.5, lr_w=0.5, gamma=0.1, eta=0.3, chopper_p=0.1),
        name="erider")
    # every parameter on the E-RIDER policy; add more (pattern, policy)
    # rules to mix devices/algorithms per path — first match wins
    plan = AnalogPlan.of(("**", policy))
    cfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
    )
    trainer = AnalogTrainer(loss_fn, cfg, plan=plan)
    state = trainer.init(jax.random.PRNGKey(2), {"w": jnp.zeros((32, 32))})
    step = trainer.jit_step()

    print("step   loss     ||Q - w*||^2 (SP tracking)   pulses")
    for i in range(601):
        state, m = step(state, jnp.zeros(()))
        if i % 100 == 0:
            print(f"{i:5d}  {float(m['true_loss']):7.4f}  "
                  f"{float(m['tile/sp_err']):10.4f}               "
                  f"{float(m['tile/pulses']):6.0f}")


if __name__ == "__main__":
    main()
