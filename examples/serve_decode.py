"""Batched serving example: prefill + greedy decode over request batches,
exercising the same serve_step the decode-shape dry-run cells lower
(KV caches / recurrent state per layer family).

Run: PYTHONPATH=src python examples/serve_decode.py
"""
import sys

from repro.launch import serve


def main():
    argv = ["--arch", "mamba2-2.7b", "--smoke", "--requests", "8",
            "--batch", "4", "--prompt-len", "24", "--gen", "16"]
    argv.extend(sys.argv[1:])
    serve.main(argv)


if __name__ == "__main__":
    main()
