"""Device-model unit tests: response functions, SP ground truth, sampling."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import device


@pytest.mark.parametrize("preset", list(device.PRESETS))
def test_presets_training_friendly(preset):
    """Definition 2.1: positive-definite bounded responses."""
    cfg = device.PRESETS[preset]
    dp = device.sample_device(jax.random.PRNGKey(0), (32, 32), cfg)
    for frac in (-0.9, -0.5, 0.0, 0.5, 0.9):
        w = jnp.full((32, 32), frac * min(cfg.tau_min, cfg.tau_max))
        qp, qm = device.responses(w, dp, cfg)
        assert bool(jnp.all(qp > 0)) and bool(jnp.all(qm > 0))
        assert bool(jnp.all(qp < 50)) and bool(jnp.all(qm < 50))


@pytest.mark.parametrize("kind", ["softbounds", "exp"])
def test_symmetric_point_zeroes_G(kind):
    """Corrected eq. (110): G(w_sp) == 0 (the paper's form has a sign typo)."""
    cfg = device.DeviceConfig(kind=kind, sigma_pm=0.4, sigma_d2d=0.2)
    dp = device.sample_device(jax.random.PRNGKey(1), (64, 64), cfg)
    sp = device.symmetric_point(dp, cfg)
    _, g = device.fg(sp, dp, cfg)
    assert float(jnp.max(jnp.abs(g))) < 1e-5


def test_ref_offset_targets_sp():
    """ref_mean/ref_std sampling realizes the requested SP distribution."""
    cfg = device.DeviceConfig(sigma_pm=0.3, sigma_d2d=0.1, ref_mean=0.3, ref_std=0.2)
    dp = device.sample_device(jax.random.PRNGKey(2), (128, 128), cfg)
    sp = device.symmetric_point(dp, cfg)
    assert abs(float(jnp.mean(sp)) - 0.3) < 0.05
    assert abs(float(jnp.std(sp)) - 0.2) < 0.05
    _, g = device.fg(sp, dp, cfg)
    assert float(jnp.max(jnp.abs(g))) < 1e-5


def test_hash_sampling_matches_distribution():
    """hash-RNG device sampling has the same distribution as threefry."""
    cfg = device.DeviceConfig(sigma_pm=0.5, sigma_d2d=0.2)
    a = device.sample_device(jax.random.PRNGKey(3), (256, 256), cfg, method="threefry")
    b = device.sample_device(jax.random.PRNGKey(3), (256, 256), cfg, method="hash")
    for k in ("gamma", "rho"):
        ma, mb = float(jnp.mean(a[k])), float(jnp.mean(b[k]))
        sa, sb = float(jnp.std(a[k])), float(jnp.std(b[k]))
        assert abs(ma - mb) < 0.02, (k, ma, mb)
        assert abs(sa - sb) < 0.02, (k, sa, sb)


def test_num_states():
    cfg = device.DeviceConfig(dw_min=0.001)
    assert cfg.num_states == pytest.approx(2000.0)
