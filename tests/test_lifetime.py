"""Lifetime subsystem: drift statistics, programming-error model, GDC
math, and the serve-time t0 identity contracts.

The statistical tests regress *recovered* physics against the configured
coefficients (drift exponent by log-log regression over six decades;
programming error by the state-dependent sigma model) rather than golden
arrays — the hash-RNG layout may change salt order without changing the
model.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device import DeviceConfig, PRESETS
from repro.lifetime import (age_params, apply_lifetime, correct_params,
                            lifetime_cfg_map, path_key, program_weights,
                            signature_tree, weight_signature)
from repro.lifetime import drift as ldrift
from repro.lifetime import gdc as lgdc

PCM = PRESETS["pcm_gst"]
KEY = jax.random.PRNGKey(7)


# --------------------------------------------------------------- drift law


def test_drift_exponent_recovered_by_regression():
    """Mean decay over 6 decades regresses to nu within the d2d spread."""
    cfg = DeviceConfig(kind="softbounds", drift_nu=0.06, drift_nu_std=0.02,
                       drift_t0=20.0)
    w = jnp.ones((256, 256), jnp.float32)
    ts = np.array([cfg.drift_t0 * 10.0 ** k for k in range(7)])
    means = np.array([float(jnp.mean(apply_lifetime(w, t, KEY, cfg)))
                      for t in ts])
    # W(t)/W(t0) = exp(-nu log r): slope of log(mean) vs log(t/t0) = -nu_eff
    x = np.log(ts / cfg.drift_t0)
    slope = np.polyfit(x[1:], np.log(means[1:]), 1)[0]
    # E[exp(-nu L)] has a positive Jensen correction ~ nu_std^2 L / 2, so
    # the recovered exponent sits slightly below drift_nu
    assert -slope == pytest.approx(cfg.drift_nu, abs=0.015)


def test_drift_t0_is_bit_exact_noop():
    w = jax.random.normal(KEY, (64, 48), jnp.float32)
    out = apply_lifetime(w, PCM.drift_t0, KEY, PCM)
    assert np.array_equal(np.asarray(out), np.asarray(w))


def test_drift_monotone_and_clamped_below_t0():
    cfg = DeviceConfig(kind="softbounds", drift_nu=0.06, drift_t0=20.0)
    w = jnp.ones((128, 128), jnp.float32)
    ms = [float(jnp.mean(apply_lifetime(w, t, KEY, cfg)))
          for t in (20.0, 2e2, 2e3, 2e4)]
    assert all(a > b for a, b in zip(ms, ms[1:]))
    # t < t0 clamps to the t0 read (drift undefined before programming)
    early = apply_lifetime(w, 1.0, KEY, cfg)
    ref = apply_lifetime(w, cfg.drift_t0 + 0.0, KEY, cfg)
    assert np.array_equal(np.asarray(early), np.asarray(ref))


def test_drift_deterministic_across_calls_and_jit():
    """Hash-RNG draws are frozen per (key, shape): re-reading at the same
    t returns the same array, jitted or not."""
    w = jax.random.normal(KEY, (32, 32), jnp.float32)
    a = apply_lifetime(w, 1e6, KEY, PCM)
    b = apply_lifetime(w, 1e6, KEY, PCM)
    fn = jax.jit(lambda x: apply_lifetime(x, 1e6, KEY, PCM))
    c, d = fn(w), fn(w)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(c), np.asarray(d))
    # eager vs jit may differ by fusion reordering, but only in the ULPs
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=1e-5, atol=1e-6)


def test_read_noise_scales_with_tensor_amplitude():
    """read_noise is a conductance-range fraction: the model-space sigma
    follows the tensor's amplitude."""
    cfg = DeviceConfig(kind="softbounds", read_noise=0.01, drift_t0=1.0)
    t = 100.0
    for amp in (0.05, 5.0):
        w = amp * jnp.ones((512, 512), jnp.float32)
        noise = np.asarray(apply_lifetime(w, t, KEY, cfg)) - amp
        assert np.std(noise) == pytest.approx(cfg.read_noise * amp, rel=0.1)


# ------------------------------------------------------------- programming


def test_program_weights_state_dependent_sigma():
    """Open-loop (prog_rounds=1) error std follows sigma_p(w) =
    prog_noise + prog_noise_slope * |w|."""
    cfg = DeviceConfig(kind="softbounds", tau_min=100.0, tau_max=100.0,
                       prog_noise=0.01, prog_noise_slope=0.08, prog_rounds=1)
    for target in (0.0, 0.5, 2.0):
        w = jnp.full((512, 512), target, jnp.float32)
        err = np.asarray(program_weights(w, KEY, cfg)) - target
        want = cfg.prog_noise + cfg.prog_noise_slope * abs(target)
        assert np.std(err) == pytest.approx(want, rel=0.1)


def test_program_weights_verify_rounds_contract_error():
    """Write-and-verify shrinks the residual vs open-loop programming."""
    base = dict(kind="softbounds", tau_min=100.0, tau_max=100.0,
                prog_noise=0.02, prog_noise_slope=0.1, read_noise=0.002)
    w = jax.random.normal(KEY, (256, 256), jnp.float32)
    rms = []
    for rounds in (1, 3):
        cfg = DeviceConfig(prog_rounds=rounds, **base)
        rms.append(float(jnp.sqrt(jnp.mean(
            (program_weights(w, KEY, cfg) - w) ** 2))))
    assert rms[1] < 0.35 * rms[0], rms


def test_program_weights_noop_without_noise():
    cfg = DeviceConfig(kind="softbounds")
    w = jax.random.normal(KEY, (16, 16), jnp.float32)
    assert program_weights(w, KEY, cfg) is w


# --------------------------------------------------------------------- GDC


def test_signature_chunking_invariant():
    """The scan-chunked signature equals the direct one-shot reduction
    (padding rows contribute nothing)."""
    w = jax.random.normal(KEY, (37, 19), jnp.float32)  # rows % chunks != 0
    direct = float(weight_signature(w, chunks=1))
    for chunks in (2, 4, 8):
        assert float(weight_signature(w, chunks=chunks)) == \
            pytest.approx(direct, rel=1e-5)


def test_gdc_alpha_recovers_global_scale():
    w = jax.random.normal(KEY, (64, 64), jnp.float32)
    params = {"stack": {"w": w}}
    sig0 = {p: float(v) for p, v in
            signature_tree(params, ("stack/w",)).items()}
    aged = {"stack": {"w": 0.425 * w}}
    corrected, scales = correct_params(aged, sig0)
    assert scales["stack/w"] == pytest.approx(1.0 / 0.425, rel=1e-4)
    err = np.abs(np.asarray(corrected["stack"]["w"]) - np.asarray(w))
    assert float(err.max()) < 1e-4


def test_gdc_t0_bit_exact_roundtrip():
    """signature -> json float -> alpha == 1.0 -> multiply is a no-op."""
    w = jax.random.normal(KEY, (48, 32), jnp.float32)
    params = {"w": w}
    sig = signature_tree(params, ("w",))
    stored = json.loads(json.dumps({p: float(v) for p, v in sig.items()}))
    corrected, scales = correct_params(params, stored)
    assert scales["w"] == 1.0
    assert np.array_equal(np.asarray(corrected["w"]), np.asarray(w))


def test_gdc_reduces_drift_error_at_one_year():
    cfg = PCM
    w = 0.05 * jax.random.normal(KEY, (128, 128), jnp.float32)
    params = {"w": w}
    sig0 = {p: float(v) for p, v in signature_tree(params, ("w",)).items()}
    aged = {"w": apply_lifetime(w, cfg.drift_t0 + 31557600.0,
                                path_key(KEY, "w"), cfg)}
    corrected, scales = correct_params(aged, sig0)
    err_raw = float(jnp.mean(jnp.abs(aged["w"] - w)))
    err_gdc = float(jnp.mean(jnp.abs(corrected["w"] - w)))
    assert scales["w"] > 1.5          # a year of nu~0.06 drift
    assert err_gdc < 0.5 * err_raw    # global scale removes most of it


def test_age_params_only_touches_mapped_paths():
    w = jax.random.normal(KEY, (8, 8), jnp.float32)
    b = jnp.ones((8,), jnp.float32)
    tree = {"layer": {"w": w, "b": b}}
    out = age_params(tree, {"layer/w": PCM}, 31557600.0, KEY)
    assert not np.array_equal(np.asarray(out["layer"]["w"]), np.asarray(w))
    assert out["layer"]["b"] is b


def test_path_key_distinct_per_path():
    k1 = path_key(KEY, "stack.0.attn.wq")
    k2 = path_key(KEY, "stack.1.attn.wq")
    assert not np.array_equal(np.asarray(k1), np.asarray(k2))


# ------------------------------------------------------- serve CLI plumbing


def test_parse_age_units():
    from repro.launch.serve import parse_age

    assert parse_age("0") == 0.0
    assert parse_age("90s") == 90.0
    assert parse_age("1.5h") == pytest.approx(5400.0)
    assert parse_age("1yr") == pytest.approx(31557600.0)
    with pytest.raises(ValueError):
        parse_age("10 parsecs")
    with pytest.raises(ValueError):
        parse_age("fast")


def test_presets_lifetime_fields_are_sane():
    for name, cfg in PRESETS.items():
        assert cfg.drift_nu >= 0.0 and cfg.drift_nu_std >= 0.0
        assert cfg.drift_t0 > 0.0 and cfg.prog_rounds >= 1
        assert cfg.read_noise >= 0.0 and cfg.prog_noise >= 0.0
    assert PRESETS["ideal"].drift_nu == 0.0
    assert not ldrift.has_lifetime(PRESETS["ideal"])
    assert ldrift.has_lifetime(PRESETS["pcm_gst"])


def test_reference_input_fixed_and_positive():
    x = np.asarray(lgdc.reference_input(257))
    y = np.asarray(lgdc.reference_input(257))
    assert np.array_equal(x, y)
    assert (x >= 0.5).all() and (x < 1.0).all()
