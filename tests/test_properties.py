"""Property-based tests (hypothesis) for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import device
from repro.kernels import fastrng, ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

floats = st.floats(-0.9, 0.9, allow_nan=False, width=32)


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 0.6), st.floats(0.0, 0.3))
def test_symmetric_point_property(seed, sigma_pm, sigma_d2d):
    """For any sampled device, G(symmetric_point) == 0 and the SP is inside
    the dynamic range."""
    cfg = device.DeviceConfig(sigma_pm=sigma_pm, sigma_d2d=sigma_d2d)
    dp = device.sample_device(jax.random.PRNGKey(seed), (16, 16), cfg)
    sp = device.symmetric_point(dp, cfg)
    _, g = device.fg(sp, dp, cfg)
    assert float(jnp.max(jnp.abs(g))) < 1e-4
    assert float(jnp.max(jnp.abs(sp))) <= 1.0 + 1e-6


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.001, 0.2))
def test_stochastic_rounding_unbiased(seed, frac):
    """E[stochastic_round(x)] == x for the Bernoulli rounding in the fused
    update (Assumption 3.4 zero-mean discretization)."""
    key = jax.random.PRNGKey(seed)
    dw_min = 0.01
    dw = jnp.full((64, 64), frac * dw_min)
    gamma = jnp.ones((64, 64))
    rho = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    acc = 0.0
    n = 40
    for i in range(n):
        ks = jax.random.split(jax.random.fold_in(key, i), 2)
        ubits = jax.random.bits(ks[0], (64, 64), dtype=jnp.uint32)
        zeta = jnp.zeros((64, 64))
        out = ref.analog_update_ref(w, dw, gamma, rho, ubits, zeta,
                                    dw_min=dw_min, tau_min=1.0, tau_max=1.0,
                                    sigma_c2c=0.0)
        acc += float(jnp.mean(out))
    # with gamma=1, rho=0, F=1: E[out] = dw
    se = dw_min / np.sqrt(n * 64 * 64)  # rounding std ~ dw_min/2
    assert abs(acc / n - frac * dw_min) < 6 * se


@given(st.floats(0.05, 0.95))
def test_ema_filter_is_lowpass(eta):
    """Lemma 3.10: |H(e^jw)|^2 is maximal at w=0, minimal at w=pi, and
    monotonically decreasing in between."""
    w = np.linspace(0, np.pi, 64)
    h2 = eta ** 2 / (1 + (1 - eta) ** 2 - 2 * (1 - eta) * np.cos(w))
    assert h2[0] == max(h2)
    assert h2[-1] == min(h2)
    assert np.all(np.diff(h2) <= 1e-12)
    np.testing.assert_allclose(h2[0], 1.0, rtol=1e-6)  # unit DC gain


@given(st.integers(0, 2 ** 31 - 1))
def test_hash_rng_statistics(seed):
    """Fused hash RNG: uniform mean/var and near-standard-normal moments."""
    s = jnp.array([seed & 0xFFFFFFFF, (seed * 7919) & 0xFFFFFFFF], jnp.uint32)
    u = np.asarray(fastrng.hash_uniform(s, (128, 128), 3))
    assert abs(u.mean() - 0.5) < 0.02
    assert abs(u.var() - 1 / 12) < 0.01
    z = np.asarray(fastrng.hash_normal(s, (128, 128), 5))
    assert abs(z.mean()) < 0.05
    assert abs(z.std() - 1.0) < 0.05


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 0.3))
def test_analog_update_lipschitz(seed, mag):
    """Lemma A.2: the analog increment is q_max-Lipschitz in dw."""
    cfg = device.DeviceConfig(sigma_pm=0.3, sigma_d2d=0.1)
    key = jax.random.PRNGKey(seed)
    dp = device.sample_device(key, (32, 32), cfg)
    w = jax.random.uniform(key, (32, 32), jnp.float32, -0.5, 0.5)
    qp, qm = device.responses(w, dp, cfg)
    q_max = float(jnp.max(jnp.maximum(qp, qm)))
    dw1 = mag * jax.random.normal(jax.random.fold_in(key, 1), (32, 32))
    dw2 = mag * jax.random.normal(jax.random.fold_in(key, 2), (32, 32))

    def incr(dw):
        f, g = device.fg(w, dp, cfg)
        return dw * f - jnp.abs(dw) * g

    lhs = float(jnp.linalg.norm(incr(dw1) - incr(dw2)))
    rhs = q_max * float(jnp.linalg.norm(dw1 - dw2))
    assert lhs <= rhs * (1 + 1e-5)


@given(st.integers(2, 64), st.integers(2, 64))
def test_procedural_dataset_shapes(h, n):
    from repro.data import procedural_images

    x, y = procedural_images(n, n_classes=4, size=max(h, 8), seed=1)
    assert x.shape == (n, max(h, 8), max(h, 8), 1)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(4)))
