"""Distribution tests: sharding rules, compressed all-reduce, fault tooling,
plus an 8-device subprocess mini dry-run (devices can't be re-pinned inside
this pytest process)."""
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# sharding rules (pure logic; 1 device is fine for spec construction)
# ---------------------------------------------------------------------------


def test_param_spec_rules():
    from repro.distributed.sharding import param_spec
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)  # single device: divisibility forces replication
    spec = param_spec("stack/body/p0/mlp/wi", (24, 896, 4864), mesh)
    assert spec == P(None, None, None)


def test_param_spec_divisibility_fallback():
    """Dims not divisible by the mesh axis fall back to replication."""
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 8}

    spec = sh.param_spec("attn/wq", (30, 64), FakeMesh())
    assert spec == P(None, "model")
    spec2 = sh.param_spec("attn/wq", (30, 20), FakeMesh())  # 20 % 8 != 0
    assert spec2 == P(None, None)
    spec3 = sh.param_spec("mlp/wi", (32, 64), FakeMesh(), zero=True)
    assert spec3 == P("data", "model")


def test_cache_spec_long_context():
    """batch=1 decode: sequence dim gets the data axes."""
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    tree = {"kv": {"k": jax.ShapeDtypeStruct((1, 524288, 4, 256), jnp.bfloat16),
                   "pos": jax.ShapeDtypeStruct((524288,), jnp.int32)}}
    # cache_shardings needs a real Mesh for NamedSharding; use spec logic via
    # a real host mesh when >= 2 devices, else just smoke the function
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    sh.cache_shardings(tree, mesh)  # must not raise


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_slow_step():
    from repro.distributed.fault import StragglerMonitor

    m = StragglerMonitor(threshold=3.0, warmup=3)
    for _ in range(5):
        m.start()
        time.sleep(0.01)
        m.stop()
    m.start()
    time.sleep(0.2)
    assert m.stop() is True
    assert m.flagged == 1


def test_preemption_and_restart():
    from repro.distributed.fault import PreemptionHandler, RestartPolicy

    h = PreemptionHandler(install=False)
    assert not h.should_stop
    h.trigger()
    assert h.should_stop

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return "ok"

    rp = RestartPolicy(max_restarts=5, backoff_s=0.01)
    assert rp.run(flaky) == "ok"
    assert rp.restarts == 2


# ---------------------------------------------------------------------------
# subprocess multi-device tests
# ---------------------------------------------------------------------------


def _run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compressed_allreduce_subprocess():
    out = _run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_host_mesh
from repro.distributed.compression import make_compressed_grad_fn
mesh = make_host_mesh(8, 1)
fn = make_compressed_grad_fn(mesh, "data")
g = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))
err = jnp.zeros_like(g)
mean, new_err = fn(g, err)
true_mean = jnp.mean(g, axis=0, keepdims=True)
# every row of `mean` should equal the true mean within int8 quantization
diff = float(jnp.max(jnp.abs(mean - true_mean)))
scale = float(jnp.max(jnp.abs(g))) / 127
assert diff < 3 * scale, (diff, scale)
# error feedback accumulates the residual
assert float(jnp.max(jnp.abs(new_err))) <= scale * 1.01
print("COMPRESSION_OK")
""")
    assert "COMPRESSION_OK" in out


def test_mini_dryrun_subprocess():
    """8-device (2x2x2) multi-pod mini dry-run: train + decode cells lower,
    compile, and produce roofline JSONs."""
    out = _run_sub("""
import os
from repro.launch import dryrun
from repro.launch.mesh import make_mesh
def small_mesh(*, multi_pod=False):
    if multi_pod:
        return make_mesh((2,2,2), ("pod","data","model"))
    return make_mesh((2,4), ("data","model"))
dryrun.make_production_mesh = small_mesh
r1 = dryrun.run_cell("qwen2-0.5b", "decode_32k", multi_pod=True, out_dir="/tmp/dry_test", tag="pytest")
r2 = dryrun.run_cell("mamba2-2.7b", "long_500k", multi_pod=False, out_dir="/tmp/dry_test", tag="pytest")
assert r1["status"] == "ok", r1
assert r2["status"] == "ok", r2
assert r1["roofline"]["hlo_flops"] > 0
print("DRYRUN_OK")
""", timeout=560)
    assert "DRYRUN_OK" in out


def test_elastic_restore_subprocess():
    """Checkpoint saved on one mesh restores onto a different mesh shape."""
    out = _run_sub("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt
from repro.launch.mesh import make_host_mesh
d = tempfile.mkdtemp()
mesh1 = make_host_mesh(4, 2)
x = jax.device_put(jnp.arange(64*32, dtype=jnp.float32).reshape(64, 32),
                   NamedSharding(mesh1, P("data", "model")))
ckpt.save({"x": x}, d, step=1)
mesh2 = make_host_mesh(2, 4)   # different factorization = elastic rescale
sh = {"x": NamedSharding(mesh2, P("data", "model"))}
restored = ckpt.restore({"x": x}, d, shardings=sh)
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert restored["x"].sharding.spec == P("data", "model")
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
