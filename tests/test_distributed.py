"""Distribution tests: sharding rules, compressed all-reduce, fault tooling,
plus an 8-device subprocess mini dry-run (devices can't be re-pinned inside
this pytest process)."""
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# sharding rules (pure logic; 1 device is fine for spec construction)
# ---------------------------------------------------------------------------


def test_param_spec_rules():
    from repro.distributed.sharding import param_spec
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)  # single device: divisibility forces replication
    spec = param_spec("stack/body/p0/mlp/wi", (24, 896, 4864), mesh)
    assert spec == P(None, None, None)


def test_param_spec_divisibility_fallback():
    """Dims not divisible by the mesh axis fall back to replication."""
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 8}

    spec = sh.param_spec("attn/wq", (30, 64), FakeMesh())
    assert spec == P(None, "model")
    spec2 = sh.param_spec("attn/wq", (30, 20), FakeMesh())  # 20 % 8 != 0
    assert spec2 == P(None, None)
    spec3 = sh.param_spec("mlp/wi", (32, 64), FakeMesh(), zero=True)
    assert spec3 == P("data", "model")


def test_rule_template_tags():
    """Mesh-independent rule templates drive spec-aware tile grouping."""
    from repro.distributed.sharding import rule_template, template_tag

    assert template_tag(rule_template("l0/attn/wq", 2)) == "nM"
    assert template_tag(rule_template("l0/attn/wo", 2)) == "Mn"
    assert template_tag(rule_template("stack/body/p0/mlp/wi", 3)) == "nnM"
    assert template_tag(rule_template("unmatched", 2)) == "nn"
    assert template_tag(()) == "s"


def test_merge_specs():
    from repro.distributed.sharding import merge_specs

    assert merge_specs([P("data", None, "model"), P("data", "model", None)]) \
        == P("data", None, None)
    assert merge_specs([P("data", None, "model")]) == P("data", None, "model")


def test_grouped_tile_spec_multi_pod_stack():
    """Multi-pod ZeRO: the stack axis takes pod x data when divisible."""
    from repro.distributed.sharding import grouped_tile_spec

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 8}

    spec = grouped_tile_spec(("attn/wq",), (16, 32, 64), FakeMesh(),
                             zero=True)
    assert spec == P(("pod", "data"), None, "model")


def test_cache_spec_long_context():
    """batch=1 decode: sequence dim gets the data axes."""
    import repro.distributed.sharding as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    tree = {"kv": {"k": jax.ShapeDtypeStruct((1, 524288, 4, 256), jnp.bfloat16),
                   "pos": jax.ShapeDtypeStruct((524288,), jnp.int32)}}
    # cache_shardings needs a real Mesh for NamedSharding; use spec logic via
    # a real host mesh when >= 2 devices, else just smoke the function
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    sh.cache_shardings(tree, mesh)  # must not raise


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_slow_step():
    from repro.distributed.fault import StragglerMonitor

    m = StragglerMonitor(threshold=3.0, warmup=3)
    for _ in range(5):
        m.start()
        time.sleep(0.01)
        m.stop()
    m.start()
    time.sleep(0.2)
    assert m.stop() is True
    assert m.flagged == 1


def test_preemption_and_restart():
    from repro.distributed.fault import PreemptionHandler, RestartPolicy

    h = PreemptionHandler(install=False)
    assert not h.should_stop
    h.trigger()
    assert h.should_stop

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return "ok"

    rp = RestartPolicy(max_restarts=5, backoff_s=0.01)
    assert rp.run(flaky) == "ok"
    assert rp.restarts == 2


# ---------------------------------------------------------------------------
# subprocess multi-device tests
# ---------------------------------------------------------------------------


def _run_sub(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compressed_allreduce_subprocess():
    out = _run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_host_mesh
from repro.distributed.compression import make_compressed_grad_fn
mesh = make_host_mesh(8, 1)
fn = make_compressed_grad_fn(mesh, "data")
g = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))
err = jnp.zeros_like(g)
mean, new_err = fn(g, err)
true_mean = jnp.mean(g, axis=0, keepdims=True)
# every row of `mean` should equal the true mean within int8 quantization
diff = float(jnp.max(jnp.abs(mean - true_mean)))
scale = float(jnp.max(jnp.abs(g))) / 127
assert diff < 3 * scale, (diff, scale)
# error feedback accumulates the residual
assert float(jnp.max(jnp.abs(new_err))) <= scale * 1.01
print("COMPRESSION_OK")
""")
    assert "COMPRESSION_OK" in out


def test_mini_dryrun_subprocess():
    """8-device (2x2x2) multi-pod mini dry-run: train + decode cells lower,
    compile, and produce roofline JSONs."""
    out = _run_sub("""
import os
from repro.launch import dryrun
from repro.launch.mesh import make_mesh
def small_mesh(*, multi_pod=False):
    if multi_pod:
        return make_mesh((2,2,2), ("pod","data","model"))
    return make_mesh((2,4), ("data","model"))
dryrun.make_production_mesh = small_mesh
r1 = dryrun.run_cell("qwen2-0.5b", "decode_32k", multi_pod=True, out_dir="/tmp/dry_test", tag="pytest")
r2 = dryrun.run_cell("mamba2-2.7b", "long_500k", multi_pod=False, out_dir="/tmp/dry_test", tag="pytest")
assert r1["status"] == "ok", r1
assert r2["status"] == "ok", r2
assert r1["roofline"]["hlo_flops"] > 0
print("DRYRUN_OK")
""", timeout=560)
    assert "DRYRUN_OK" in out


def test_sharded_tile_bank_2x2_subprocess():
    """Acceptance criterion: on a 2x2 (data, model) mesh, same-shape tiles
    with different partition rules occupy distinct groups whose stacks carry
    the model axis, the stack dim takes the ZeRO/data axis, per-device
    tile-state bytes drop by ~the data size vs replicated, and the grouped
    train_step runs under the explicit specs."""
    out = _run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.tile import TileConfig
from repro.core.trainer import AnalogTrainer, TrainerConfig
from repro.distributed.sharding import state_shardings
from repro.launch.mesh import make_host_mesh

assert make_host_mesh(2, 1, pods=2).axis_names == ("pod", "data", "model")
mesh = make_host_mesh(2, 2)
dev = DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1, sigma_c2c=0.05)
cfg = TrainerConfig(
    tile=TileConfig(algorithm="erider", device_p=dev, device_w=dev),
    digital=DigitalOptConfig(kind="sgd"),
    schedule=ScheduleConfig(kind="constant", base_lr=0.1))
def loss(params, batch, rng):
    return sum(jnp.sum(v ** 2) for _, v in sorted(params.items())), {}
trainer = AnalogTrainer(loss, cfg, analog_filter=lambda p, l: True, mesh=mesh)
params = {}
for i in range(2):
    params[f"l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
    params[f"l{i}/attn/wo"] = 0.1 * jnp.ones((8, 8))
state = trainer.init(jax.random.PRNGKey(0), params)
names = set(g for g, _ in state["tiles"].index)
assert names == {"g8x8_float32_nM", "g8x8_float32_Mn"}, names
cname = "g8x8_float32_Mn+g8x8_float32_nM"
assert [c for c, _ in state["tiles"].class_index] == [cname]
sh = state_shardings(state, mesh)
# class axis replicates (scan axis); stack axis takes ZeRO/data; member dims
# are the dim-wise agreement of nM and Mn rules (conflict -> replicate)
assert sh["tiles"].classes[cname]["W"].spec == P(None, "data", None, None)
assert sh["tiles"].classes[cname]["t"].spec == P(None, None)
state = jax.device_put(state, sh)
total = sum(l.nbytes for l in jax.tree.leaves(state["tiles"]))
per_dev = sum(l.addressable_shards[0].data.nbytes
              for l in jax.tree.leaves(state["tiles"]))
assert per_dev <= total / 2 + 1024, (per_dev, total)   # ~ZeRO/data factor
step = jax.jit(trainer.train_step, in_shardings=(sh, None), donate_argnums=(0,))
for _ in range(2):
    state, m = step(state, jnp.zeros(()))
w = state["tiles"].classes[cname]["W"]
wspec = tuple(w.sharding.spec) + (None,) * (w.ndim - len(w.sharding.spec))
assert wspec == (None, "data", None, None), w.sharding
assert np.isfinite(float(m["loss"]))
print("SHARDED_BANK_OK", per_dev, total)
""", devices=4)
    assert "SHARDED_BANK_OK" in out


def test_elastic_restore_subprocess():
    """Checkpoint saved on one mesh restores onto a different mesh shape."""
    out = _run_sub("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt
from repro.launch.mesh import make_host_mesh
d = tempfile.mkdtemp()
mesh1 = make_host_mesh(4, 2)
x = jax.device_put(jnp.arange(64*32, dtype=jnp.float32).reshape(64, 32),
                   NamedSharding(mesh1, P("data", "model")))
ckpt.save({"x": x}, d, step=1)
mesh2 = make_host_mesh(2, 4)   # different factorization = elastic rescale
sh = {"x": NamedSharding(mesh2, P("data", "model"))}
restored = ckpt.restore({"x": x}, d, shardings=sh)
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert restored["x"].sharding.spec == P("data", "model")
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
