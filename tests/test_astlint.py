"""AST linter: every rule fires on a synthetic repro, every escape works,
and the real source tree is clean."""
import os

from repro.analysis import run_lint
from repro.analysis.astlint import lint_source

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

HOT = "src/repro/core/fake.py"
COLD = "src/repro/launch/fake.py"


def _rules(src, path=HOT):
    return [f.rule for f in lint_source(src, path)]


# ---------------------------------------------------------------------------
# host-rng
# ---------------------------------------------------------------------------


def test_np_random_flagged():
    src = "import numpy as np\ndef f(): return np.random.normal()\n"
    assert _rules(src) == ["host-rng"]


def test_stdlib_random_flagged():
    src = "import random\ndef f(): return random.gauss(0, 1)\n"
    assert _rules(src) == ["host-rng"]


def test_host_rng_allowed_in_data_package():
    src = "import numpy as np\ndef f(): return np.random.normal()\n"
    assert _rules(src, "src/repro/data/synthetic.py") == []


def test_jax_random_not_flagged():
    src = ("import jax\n"
           "def f(key): return jax.random.normal(key, (3,))\n")
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# prngkey-reuse
# ---------------------------------------------------------------------------


def test_duplicate_literal_seed_flagged():
    src = ("import jax\n"
           "def f(): return jax.random.PRNGKey(0)\n"
           "def g(): return jax.random.PRNGKey(0)\n")
    fs = lint_source(src, HOT)
    assert [f.rule for f in fs] == ["prngkey-reuse"]
    assert fs[0].line == 3


def test_distinct_seeds_and_nonliteral_ok():
    src = ("import jax\n"
           "def f(): return jax.random.PRNGKey(0)\n"
           "def g(seed): return jax.random.PRNGKey(seed)\n"
           "def h(): return jax.random.PRNGKey(1)\n")
    assert _rules(src) == []


def test_from_import_prngkey_detected():
    src = ("from jax.random import PRNGKey\n"
           "a = PRNGKey(7)\nb = PRNGKey(7)\n")
    assert _rules(src) == ["prngkey-reuse"]


# ---------------------------------------------------------------------------
# tracer-sync
# ---------------------------------------------------------------------------


def test_item_flagged_everywhere():
    src = "def f(x): return x.item()\n"
    assert _rules(src, COLD) == ["tracer-sync"]


def test_np_asarray_flagged_only_in_hot_packages():
    src = "import numpy as np\ndef f(x): return np.asarray(x)\n"
    assert _rules(src, HOT) == ["tracer-sync"]
    assert _rules(src, COLD) == []


def test_float_of_jnp_call_flagged():
    src = "import jax.numpy as jnp\ndef f(x): return float(jnp.sum(x))\n"
    assert _rules(src) == ["tracer-sync"]
    # float() of plain python is fine
    assert _rules("def f(x): return float(len(x))\n") == []


def test_local_numpy_import_marks_host_function():
    src = ("def f(x):\n"
           "    import numpy as np\n"
           "    return float(np.asarray(x).sum().item())\n")
    assert _rules(src) == []


def test_pragma_suppresses():
    src = "def f(x): return x.item()  # graphlint: allow\n"
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# mutable-default-config
# ---------------------------------------------------------------------------


def test_mutable_default_in_frozen_dataclass_flagged():
    src = ("import dataclasses\n"
           "@dataclasses.dataclass(frozen=True)\n"
           "class Thing:\n"
           "    xs: tuple = ()\n"
           "    ys: list = dataclasses.field(default_factory=list)\n")
    assert _rules(src) == ["mutable-default-config"]


def test_config_suffix_counts_as_static():
    src = ("from dataclasses import dataclass, field\n"
           "@dataclass\n"
           "class RunConfig:\n"
           "    opts: dict = field(default_factory=dict)\n")
    assert _rules(src) == ["mutable-default-config"]


def test_plain_dataclass_may_use_default_factory():
    src = ("from dataclasses import dataclass, field\n"
           "@dataclass\n"
           "class Accum:\n"
           "    vals: list = field(default_factory=list)\n")
    assert _rules(src) == []


def test_tuple_factory_is_fine():
    src = ("import dataclasses\n"
           "@dataclasses.dataclass(frozen=True)\n"
           "class FooConfig:\n"
           "    xs: tuple = dataclasses.field(default_factory=tuple)\n")
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# module-level-jnp
# ---------------------------------------------------------------------------


def test_module_level_jnp_call_flagged():
    src = "import jax.numpy as jnp\nTABLE = jnp.arange(16)\n"
    assert _rules(src) == ["module-level-jnp"]


def test_jnp_attribute_access_at_module_level_ok():
    # dtype aliases etc. are attribute reads, not device computation
    src = "import jax.numpy as jnp\nDTYPE = jnp.float32\n"
    assert _rules(src) == []


def test_jnp_inside_function_ok():
    src = "import jax.numpy as jnp\ndef f(): return jnp.arange(16)\n"
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# whole tree
# ---------------------------------------------------------------------------


def test_repo_source_is_clean():
    findings = run_lint(SRC)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_syntax_error_reported_not_raised():
    fs = lint_source("def f(:\n", HOT)
    assert len(fs) == 1 and fs[0].rule == "parse-error"
