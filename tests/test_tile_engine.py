"""Batched (shape-grouped) tile engine tests.

Covers the TileBank layout, the O(distinct-shapes) program-instancing
guarantee of the grouped train_step, equivalence with the legacy looped
engine, on-the-fly upgrade of legacy per-tile checkpoints, and the stacked
sharding specs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.tile import TileBank, TileConfig, group_name, parse_group_name
from repro.core.trainer import AnalogTrainer, TrainerConfig, merge_effective

DEV = DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1, sigma_c2c=0.05)


def _loss_fn(params, batch, rng):
    return sum(jnp.sum(v ** 2) for _, v in sorted(params.items())), {}


def _trainer(engine: str, algorithm: str = "erider") -> AnalogTrainer:
    cfg = TrainerConfig(
        tile=TileConfig(algorithm=algorithm, device_p=DEV, device_w=DEV,
                        lr_p=0.5, lr_w=0.5, gamma=0.1, eta=0.1, chopper_p=0.1),
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
        engine=engine,
    )
    return AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)


def _params(n_square: int = 8, shape=(16, 16)):
    p = {f"l{i}": 0.1 * jnp.ones(shape) for i in range(n_square)}
    p["odd"] = 0.1 * jnp.ones((4, 24))
    return p


def test_group_name_roundtrip():
    assert parse_group_name(group_name((64, 128), jnp.float32, "nM")) \
        == ((64, 128), "float32", "nM", "")
    assert parse_group_name(group_name((4, 8, 16), jnp.bfloat16, "Mnn")) \
        == ((4, 8, 16), "bfloat16", "Mnn", "")
    # policy-tagged keys (mixed AnalogPlan) round-trip the 4th component
    assert parse_group_name(group_name((64, 128), jnp.float32, "nM", "rider")) \
        == ((64, 128), "float32", "nM", "rider")
    assert parse_group_name("g8x8_float32_Mn_ppola") \
        == ((8, 8), "float32", "Mn", "pola")
    # legacy (shape, dtype)-only keys parse with empty tags
    assert parse_group_name(group_name((64, 128), jnp.float32)) \
        == ((64, 128), "float32", "", "")
    # tag charset is a subset of dtype charset: the dtype must not eat it
    assert parse_group_name("g8x8_float32_nn") == ((8, 8), "float32", "nn", "")
    assert parse_group_name("not_a_group/W") is None


def test_spec_aware_grouping_splits_rule_families():
    """Same-shape tiles whose owning weights shard differently (wq's
    (None, "M") vs wo's ("M", None)) must land in distinct groups so their
    stacks can carry the model axis."""
    from repro.core.tile import group_tiles

    shapes = {}
    for i in range(3):
        shapes[f"l{i}/attn/wq"] = (16, 16)
        shapes[f"l{i}/attn/wk"] = (16, 16)
        shapes[f"l{i}/attn/wo"] = (16, 16)
    index = dict(group_tiles(shapes, TileConfig()))
    assert set(index) == {"g16x16_float32_nM", "g16x16_float32_Mn"}
    assert index["g16x16_float32_nM"] == tuple(sorted(
        p for p in shapes if p.endswith(("wq", "wk"))))
    assert index["g16x16_float32_Mn"] == tuple(sorted(
        p for p in shapes if p.endswith("wo")))


def test_scan_groups_bit_identical_to_unroll():
    """Acceptance criterion: the scanned grouped engine (same-structure
    group classes under one lax.scan) is bit-identical to the unrolled
    grouped engine — the per-group CRC-folded keys are the same. Tile
    STATE must match bitwise; the mean-based telemetry scalars are only
    checked to float32 ULP precision, because XLA is free to tile the
    (value-irrelevant) metric reductions differently inside a scan body
    than in an unrolled vmap."""

    def run(scan):
        cfg = TrainerConfig(
            tile=TileConfig(algorithm="erider", device_p=DEV, device_w=DEV,
                            lr_p=0.5, lr_w=0.5, gamma=0.1, eta=0.1,
                            chopper_p=0.1),
            digital=DigitalOptConfig(kind="sgd"),
            schedule=ScheduleConfig(kind="constant", base_lr=0.1),
            scan_groups=scan,
        )
        tr = AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)
        params = {}
        for i in range(3):  # wq/wk -> nM group, wo -> Mn group: 2-group class
            params[f"l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
            params[f"l{i}/attn/wo"] = 0.1 * jnp.ones((8, 8))
        state = tr.init(jax.random.PRNGKey(5), params)
        step = tr.jit_step(donate=False)
        for _ in range(5):
            state, m = step(state, jnp.zeros(()))
        return state, m

    s_scan, m_scan = run(True)
    s_unroll, m_unroll = run(False)
    assert len(s_scan["tiles"].groups) == 2
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        s_scan["tiles"], s_unroll["tiles"])
    for k in m_scan:
        np.testing.assert_allclose(np.asarray(m_scan[k]),
                                   np.asarray(m_unroll[k]),
                                   rtol=1e-6, err_msg=k)


def test_class_name_roundtrip_and_partition():
    """Scan classes: same-structure groups share one pre-stacked pytree.
    The class key is the sorted '+'-join of its member group names, and the
    partition signature is (treedef, leaf shapes/dtypes, policy) — so the
    nM and Mn groups of one transformer block co-scan while a different
    shape or policy splits off."""
    from repro.core.tile import (TileBank, class_name, class_partition,
                                 group_name, init_tile, parse_class_name)

    assert class_name(("a", "b")) == "a+b"
    assert parse_class_name("a+b") == ("a", "b")
    assert parse_class_name("solo") == ("solo",)

    cfg = TileConfig(algorithm="erider", device_p=DEV, device_w=DEV)
    key = jax.random.PRNGKey(0)

    def stack(n, shape):
        per = [init_tile(jax.random.fold_in(key, i), 0.1 * jnp.ones(shape), cfg)
               for i in range(n)]
        return jax.tree.map(lambda *ls: jnp.stack(ls), *per)

    nm, mn = group_name((8, 8), jnp.float32, "nM"), \
        group_name((8, 8), jnp.float32, "Mn")
    odd = group_name((4, 24), jnp.float32, "nM")
    groups = {nm: stack(3, (8, 8)), mn: stack(3, (8, 8)),
              odd: stack(1, (4, 24))}
    index = tuple((g, tuple(f"{g}/p{i}" for i in range(3 if g != odd else 1)))
                  for g in (nm, mn, odd))
    cidx = class_partition(groups, index)
    assert dict(cidx) == {class_name((nm, mn)): (nm, mn), odd: (odd,)}

    bank = TileBank(groups, index)
    assert [c for c, _ in bank.class_index] == sorted(
        [class_name((nm, mn)), odd])
    # class leaves are (C, n, *member); the per-group view slices them back
    assert bank.classes[class_name((nm, mn))]["W"].shape == (2, 3, 8, 8)
    for g in (nm, mn, odd):
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), bank.groups[g], groups[g])


def test_fused_backend_bit_identical_to_vmap_hash():
    """Acceptance criterion: the fused batched pulse-update backend (one
    flattened update over each class stack, fastrng noise) is bit-identical
    to the vmap reference running rng='hash' — the per-tile hash streams
    are position-independent, so flattening (C, n) -> (C*n) changes no
    bits."""

    def run(backend):
        cfg = TrainerConfig(
            tile=TileConfig(algorithm="erider", device_p=DEV, device_w=DEV,
                            lr_p=0.5, lr_w=0.5, gamma=0.1, eta=0.1,
                            chopper_p=0.1, rng="hash",
                            update_backend=backend),
            digital=DigitalOptConfig(kind="sgd"),
            schedule=ScheduleConfig(kind="constant", base_lr=0.1),
        )
        tr = AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)
        params = {}
        for i in range(3):  # 2-group (nM + Mn) class plus an odd singleton
            params[f"l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
            params[f"l{i}/attn/wo"] = 0.1 * jnp.ones((8, 8))
        params["odd"] = 0.1 * jnp.ones((4, 24))
        state = tr.init(jax.random.PRNGKey(7), params)
        step = tr.jit_step(donate=False)
        for _ in range(5):
            state, m = step(state, jnp.zeros(()))
        return state, m

    s_f, m_f = run("fused")
    s_v, m_v = run("vmap")
    # the two banks' aux policies differ (update_backend), so compare the
    # class-keyed storage leaves directly
    assert set(s_f["tiles"].classes) == set(s_v["tiles"].classes)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        dict(s_f["tiles"].classes), dict(s_v["tiles"].classes))
    assert set(m_f) == set(m_v)
    for k in m_f:
        np.testing.assert_allclose(np.asarray(m_f[k]), np.asarray(m_v[k]),
                                   rtol=1e-6, err_msg=k)


def test_init_groups_by_shape_and_matches_looped_init():
    """Grouped init is a pure re-layout: every per-path view must be bitwise
    identical to the legacy looped init (same per-tile fold_in seeds)."""
    params = _params()
    bank = _trainer("grouped").init(jax.random.PRNGKey(0), params)["tiles"]
    looped = _trainer("looped").init(jax.random.PRNGKey(0), params)["tiles"]
    assert isinstance(bank, TileBank)
    assert len(bank) == len(params) == len(looped)
    assert len(bank.groups) == 2  # (16,16) stack of 8 + (4,24) stack of 1
    for p, ts in looped.items():
        view = bank[p]
        assert jax.tree_util.tree_structure(view) \
            == jax.tree_util.tree_structure(ts), p
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=p), view, ts)


def test_grouped_step_one_pulse_update_instance_per_shape_group():
    """Acceptance criterion: with >= 8 same-shape analog layers the jitted
    train_step contains ONE vmapped pulse-update instance per shape group,
    not per tile — the lowered program of the 8-layer model has exactly as
    many control-flow (threefry while) instances as the 1-layer model, while
    the looped engine scales them O(tiles)."""

    def lowered_text(engine, n):
        tr = _trainer(engine)
        params = {f"l{i}": 0.1 * jnp.ones((16, 16)) for i in range(n)}
        state = tr.init(jax.random.PRNGKey(0), params)
        return jax.jit(tr.train_step).lower(state, jnp.zeros(())).as_text()

    whiles_grouped_1 = lowered_text("grouped", 1).count("stablehlo.while")
    text_grouped_8 = lowered_text("grouped", 8)
    whiles_grouped_8 = text_grouped_8.count("stablehlo.while")
    text_looped_8 = lowered_text("looped", 8)
    whiles_looped_8 = text_looped_8.count("stablehlo.while")

    assert whiles_grouped_8 == whiles_grouped_1, (
        whiles_grouped_8, whiles_grouped_1)
    assert whiles_looped_8 >= whiles_grouped_8 + 7, (
        whiles_looped_8, whiles_grouped_8)
    # the program itself must stop scaling with layer count
    assert len(text_grouped_8) < 0.6 * len(text_looped_8)


@pytest.mark.parametrize("algorithm", ["sgd", "ttv2", "agad", "rider", "erider"])
def test_grouped_trains_like_looped(algorithm):
    """Both engines reduce the quadratic loss to a comparable level (exact
    bits differ: the grouped engine uses split-once-per-group keys)."""

    def run(engine):
        tr = _trainer(engine, algorithm)
        state = tr.init(jax.random.PRNGKey(3), _params(4))
        step = tr.jit_step(donate=False)
        m = {}
        for _ in range(60):
            state, m = step(state, jnp.zeros(()))
        return state, {k: float(v) for k, v in m.items()}

    s_g, m_g = run("grouped")
    s_l, m_l = run("looped")
    initial = float(_loss_fn(_params(4), None, None)[0])
    # engine parity is the claim here (convergence quality per algorithm is
    # test_algorithms'); agad's thresholded transfer barely moves in 60 steps
    assert m_g["loss"] < initial, (algorithm, m_g["loss"], initial)
    assert abs(m_g["loss"] - m_l["loss"]) < 0.25 * max(m_l["loss"], 1e-3), \
        (algorithm, m_g["loss"], m_l["loss"])
    # same metric names out of both engines
    assert set(m_g) == set(m_l)


def test_grouped_metrics_aggregate_over_all_tiles():
    tr = _trainer("grouped")
    state = tr.init(jax.random.PRNGKey(0), _params())
    _, m = tr.jit_step(donate=False)(state, jnp.zeros(()))
    for k in ("tile/pulses", "tile/gp_sq", "tile/sp_err", "tile/prog_events"):
        assert np.isfinite(float(m[k])), k


def test_abstract_state_matches_init_structure():
    """Dry-run lowering depends on abstract_state agreeing with init."""
    tr = _trainer("grouped")
    params = _params()
    concrete = tr.init(jax.random.PRNGKey(0), params)
    abstract = tr.abstract_state(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
    cflat = jax.tree_util.tree_flatten_with_path(concrete)[0]
    aflat = jax.tree_util.tree_flatten_with_path(abstract)[0]
    assert len(cflat) == len(aflat)
    for (ckp, cleaf), (akp, aleaf) in zip(cflat, aflat):
        assert ckp == akp
        assert tuple(cleaf.shape) == tuple(aleaf.shape), (ckp, cleaf.shape, aleaf.shape)
        assert cleaf.dtype == aleaf.dtype, (ckp, cleaf.dtype, aleaf.dtype)


def test_legacy_per_tile_checkpoint_restores_into_grouped(tmp_path):
    """A checkpoint written by the legacy looped engine (per-tile layout)
    restores into the grouped TileBank template by stacking member tiles."""
    from repro.checkpoint import ckpt

    params = _params(3)
    looped = _trainer("looped")
    state_l = looped.init(jax.random.PRNGKey(0), params)
    state_l, _ = looped.jit_step(donate=False)(state_l, jnp.zeros(()))
    ckpt.save(state_l, str(tmp_path), step=1)

    grouped = _trainer("grouped")
    template = grouped.init(jax.random.PRNGKey(0), params)
    restored = ckpt.restore(template, str(tmp_path))
    assert isinstance(restored["tiles"], TileBank)
    for p in state_l["tiles"]:
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["W"]),
            np.asarray(state_l["tiles"][p]["W"]), err_msg=p)
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["Qd"]),
            np.asarray(state_l["tiles"][p]["Qd"]), err_msg=p)
    # effective weights agree between the two layouts
    eff_l = merge_effective(state_l["params"], state_l["tiles"], looped.cfg.tile)
    eff_g = merge_effective(restored["params"], restored["tiles"], grouped.cfg.tile)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b)), eff_l, eff_g)
    # and the restored grouped state steps
    restored2, m = grouped.jit_step(donate=False)(restored, jnp.zeros(()))
    assert np.isfinite(float(m["loss"]))
    assert int(restored2["step"]) == 2


def test_legacy_shape_dtype_checkpoint_rekeys_into_spec_groups(tmp_path):
    """A checkpoint written with (shape, dtype)-only group keys (one stack
    mixing wq and wo) restores into the spec-aware template: each new group
    gathers its member rows out of the old combined stack."""
    from repro.checkpoint import ckpt
    from repro.core.tile import group_name

    params = {}
    for i in range(2):
        params[f"l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
        params[f"l{i}/attn/wo"] = 0.1 * jnp.ones((8, 8))
    tr = _trainer("grouped")
    state = tr.init(jax.random.PRNGKey(1), params)
    state, _ = tr.jit_step(donate=False)(state, jnp.zeros(()))

    # rebuild the bank in the PR-1 layout: one (shape, dtype) stack holding
    # ALL tiles sorted by path (exactly what the old group_tiles produced)
    bank = state["tiles"]
    union = sorted(bank.paths())
    legacy_name = group_name((8, 8), jnp.float32)
    legacy_stack = jax.tree.map(
        lambda *leaves: jnp.stack(leaves), *(bank[p] for p in union))
    legacy_bank = TileBank({legacy_name: legacy_stack},
                           ((legacy_name, tuple(union)),))
    legacy_state = dict(state)
    legacy_state["tiles"] = legacy_bank
    ckpt.save(legacy_state, str(tmp_path), step=1)

    restored = ckpt.restore(state, str(tmp_path))
    assert set(g for g, _ in restored["tiles"].index) \
        == {"g8x8_float32_nM", "g8x8_float32_Mn"}
    for p in union:
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=p),
            restored["tiles"][p], bank[p])
    # the re-keyed state steps
    restored2, m = tr.jit_step(donate=False)(restored, jnp.zeros(()))
    assert np.isfinite(float(m["loss"]))


def test_v3_pergroup_checkpoint_restores_into_v4_bit_identical(tmp_path):
    """Acceptance criterion: a layout-v3 checkpoint (per-GROUP stacks, no
    ``tile_classes`` manifest) restores into the class-keyed v4 storage
    bit-identically, and the restored state trains bit-identically to the
    state the checkpoint was taken from. The v3 fixture is built by
    down-converting a v4 save: each (C, n, *member) class array is split
    into its C per-group (n, *member) arrays, exactly what the v3 writer
    produced."""
    import json
    import zlib

    from repro.checkpoint import ckpt

    tr = _trainer("grouped")
    params = {}
    for i in range(3):  # wq -> nM, wo -> Mn: one 2-group class, plus odd
        params[f"l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
        params[f"l{i}/attn/wo"] = 0.1 * jnp.ones((8, 8))
    params["odd"] = 0.1 * jnp.ones((4, 24))
    state = tr.init(jax.random.PRNGKey(2), params)
    step = tr.jit_step(donate=False)
    state, _ = step(state, jnp.zeros(()))
    assert any(len(gs) > 1 for _, gs in state["tiles"].class_index)
    ckpt.save(state, str(tmp_path), step=1)

    # ---- down-convert the written step to layout v3 ----
    d = tmp_path / "step_000000001"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    classes = manifest.pop("tile_classes")
    arrays = {}
    for fname in sorted({m["file"] for m in manifest["arrays"].values()}):
        with np.load(d / fname) as z:
            arrays.update({k: z[k] for k in z.files})
    new_arrays, new_meta = {}, {}
    for key, meta in manifest["arrays"].items():
        arr = arrays[meta["npz_key"]]
        parts = key.split("/")
        if len(parts) == 3 and parts[0] == "tiles" and parts[1] in classes:
            for ci, g in enumerate(classes[parts[1]]["groups"]):
                gkey = f"tiles/{g}/{parts[2]}"
                garr = arr[ci]
                safe = gkey.replace("/", "__")
                new_arrays[safe] = garr
                new_meta[gkey] = {"shape": list(garr.shape),
                                  "dtype": meta["dtype"],
                                  "file": "arrays_000.npz", "npz_key": safe,
                                  "crc32": zlib.crc32(garr.tobytes())}
        else:
            new_arrays[meta["npz_key"]] = arr
            new_meta[key] = {**meta, "file": "arrays_000.npz"}
    for fname in {m["file"] for m in manifest["arrays"].values()}:
        (d / fname).unlink()
    np.savez(d / "arrays_000.npz", **new_arrays)
    manifest["arrays"] = new_meta
    manifest["layout"] = 3
    with open(d / "manifest.json", "w") as f:
        json.dump(manifest, f)

    restored = ckpt.restore(state, str(tmp_path), verify=True)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        restored, state)
    s2a, _ = step(state, jnp.zeros(()))
    s2b, _ = step(restored, jnp.zeros(()))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        s2a["tiles"], s2b["tiles"])


def test_grouped_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt

    tr = _trainer("grouped")
    state = tr.init(jax.random.PRNGKey(0), _params(3))
    step = tr.jit_step(donate=False)
    state, _ = step(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)
    restored = ckpt.restore(state, str(tmp_path), verify=True)
    s2a, _ = step(state, jnp.zeros(()))
    s2b, _ = step(restored, jnp.zeros(()))
    for g, _paths in state["tiles"].index:
        np.testing.assert_allclose(
            np.asarray(s2a["tiles"].groups[g]["W"]),
            np.asarray(s2b["tiles"].groups[g]["W"]))


def test_grouped_tile_spec_stack_axis():
    """The stack axis is the ZeRO axis when the group size divides the data
    axes; otherwise ZeRO falls back into the member dims."""
    from repro.distributed.sharding import grouped_tile_spec
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 8}

    spec = grouped_tile_spec(("attn/wq",), (8, 30, 64), FakeMesh(), zero=True)
    assert spec == P("data", None, "model")
    spec2 = grouped_tile_spec(("attn/wq",), (3, 32, 64), FakeMesh(), zero=True)
    assert spec2 == P(None, "data", "model")
    spec3 = grouped_tile_spec(("attn/wq",), (3, 30, 64), FakeMesh(), zero=False)
    assert spec3 == P(None, None, "model")
    # same-shape members with conflicting rules (wq: (None,M), wo: (M,None))
    # must not silently transpose half the stack — member dims replicate,
    # with a one-time warning naming the offending paths
    with pytest.warns(UserWarning, match=r"attn/wo.*attn/wq"):
        spec4 = grouped_tile_spec(("attn/wo", "attn/wq"), (8, 64, 64),
                                  FakeMesh(), zero=False)
    assert spec4 == P(None, None, None)
    # ... and only once per offending stack
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        grouped_tile_spec(("attn/wo", "attn/wq"), (8, 64, 64),
                          FakeMesh(), zero=False)
    spec5 = grouped_tile_spec(("attn/wq", "mlp/wi"), (8, 30, 64),
                              FakeMesh(), zero=True)
    assert spec5 == P("data", None, "model")  # rules agree -> keep model axis


def test_state_shardings_grouped_smoke():
    """state_shardings over a grouped TrainState must produce a spec for
    every leaf (host mesh: everything replicates on 1 device)."""
    from repro.distributed.sharding import state_shardings
    from repro.launch.mesh import make_host_mesh

    tr = _trainer("grouped")
    state = tr.init(jax.random.PRNGKey(0), _params(2))
    sh = state_shardings(state, make_host_mesh(1, 1))
    n_specs = len(jax.tree.leaves(sh))
    assert n_specs == len(jax.tree.leaves(state))
