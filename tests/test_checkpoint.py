"""Checkpoint tests: roundtrip, async, integrity, restart resume, and
forward-compat of the lifetime-era DeviceConfig fields (PR 6's
stored-keys-only policy compare + the cross-plan re-key path)."""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt

# every DeviceConfig field added by the lifetime subsystem — a pre-drift
# checkpoint's stored policy JSON has none of them
LIFETIME_KEYS = ("drift_nu", "drift_nu_std", "drift_t0", "prog_noise",
                 "prog_noise_slope", "prog_rounds", "read_noise")


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "a": jax.random.normal(k, (17, 33)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": None,
                   "scalar": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=3)
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored = ckpt.restore(_tree(99), str(tmp_path), verify=True)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(restored["a"]))
    np.testing.assert_array_equal(np.asarray(t["nested"]["b"]),
                                  np.asarray(restored["nested"]["b"]))
    assert restored["nested"]["c"] is None


def test_async_save_and_latest(tmp_path):
    t = _tree()
    th = ckpt.save(t, str(tmp_path), step=1, asynchronous=True)
    th.join(timeout=30)
    ckpt.save(t, str(tmp_path), step=2)
    assert ckpt.latest_step(str(tmp_path)) == 2
    assert os.path.islink(os.path.join(str(tmp_path), "latest"))


def test_restore_shape_mismatch_fails(tmp_path):
    ckpt.save(_tree(), str(tmp_path), step=1)
    bad = {"a": jnp.zeros((5, 5)), "nested": {"b": jnp.zeros(10, jnp.int32),
                                              "c": None, "scalar": jnp.float32(0)}}
    with pytest.raises(AssertionError):
        ckpt.restore(bad, str(tmp_path))


def _drift_trainer(plan=None):
    """AnalogTrainer over a drift-aware device preset (nonzero lifetime
    coefficients end up in every stored policy JSON)."""
    from repro.api import AnalogPlan, TilePolicy
    from repro.core.device import PRESETS
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.tile import TileConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig

    dev = PRESETS["pcm_gst"]
    pol = TilePolicy(TileConfig(algorithm="erider", device_p=dev,
                                device_w=dev, lr_p=0.5, lr_w=0.5),
                     name="pcm")
    cfg = TrainerConfig(digital=DigitalOptConfig(kind="sgd"),
                        schedule=ScheduleConfig(kind="constant", base_lr=0.1))

    def loss_fn(params, batch, rng):
        return sum(jnp.sum(v ** 2) for v in params.values()), {}

    return AnalogTrainer(loss_fn, cfg,
                         plan=plan or AnalogPlan.of(("**", pol)))


def _strip_lifetime_keys(directory, step=1):
    """Rewrite a checkpoint manifest as a pre-drift writer would have:
    no lifetime keys in any stored device-config JSON."""
    path = os.path.join(directory, f"step_{step:09d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    for rec in manifest.get("tile_groups", {}).values():
        pol = rec.get("policy") or {}
        for dev_key in ("device_p", "device_w"):
            dev = pol.get("tile", {}).get(dev_key)
            if dev:
                for k in LIFETIME_KEYS:
                    dev.pop(k, None)
    with open(path, "w") as f:
        json.dump(manifest, f)
    return manifest


def test_pre_drift_checkpoint_restores_silently(tmp_path):
    """Stored-keys-only policy compare: a checkpoint whose policies were
    written before DeviceConfig grew the lifetime fields restores into a
    drift-aware template without a policy-mismatch warning."""
    trainer = _drift_trainer()
    state = trainer.init(jax.random.PRNGKey(0),
                         {"w": jnp.ones((8, 8)), "v": jnp.ones((8, 8))})
    state, _ = trainer.jit_step(donate=False)(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)
    _strip_lifetime_keys(str(tmp_path))

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        restored = ckpt.restore(state, str(tmp_path))
    for p in ("w", "v"):
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["W"]),
            np.asarray(state["tiles"][p]["W"]), err_msg=p)


def test_pre_drift_manifest_still_warns_on_real_mismatch(tmp_path):
    """Stripping the lifetime keys must not blind the compare: a stored
    key that genuinely differs (dw_min) still warns."""
    trainer = _drift_trainer()
    state = trainer.init(jax.random.PRNGKey(0), {"w": jnp.ones((8, 8))})
    ckpt.save(state, str(tmp_path), step=1)
    manifest = _strip_lifetime_keys(str(tmp_path))
    path = os.path.join(str(tmp_path), "step_000000001", "manifest.json")
    for rec in manifest["tile_groups"].values():
        rec["policy"]["tile"]["device_w"]["dw_min"] = 0.4999
    with open(path, "w") as f:
        json.dump(manifest, f)
    with pytest.warns(UserWarning, match="policy"):
        ckpt.restore(state, str(tmp_path))


def test_lifetime_fields_survive_rekey_both_directions(tmp_path):
    """Cross-plan re-key (single <-> mixed) with drift-aware policies:
    the policy JSON round-trips the lifetime fields and the re-keyed tile
    stacks are preserved in both directions."""
    from repro.api import AnalogPlan, TilePolicy
    from repro.core.device import PRESETS
    from repro.core.plan import policy_from_json, policy_to_json
    from repro.core.tile import TileConfig

    pcm = PRESETS["pcm_gst"]
    om = PRESETS["reram_om"]
    pol_pcm = TilePolicy(TileConfig(algorithm="erider", device_p=pcm,
                                    device_w=pcm, lr_p=0.5, lr_w=0.5),
                         name="pcm")
    pol_om = TilePolicy(TileConfig(algorithm="erider", device_p=om,
                                   device_w=om, lr_p=0.5, lr_w=0.5),
                        name="om")
    # the serializer keeps every lifetime coefficient
    for pol in (pol_pcm, pol_om):
        blob = policy_to_json(pol)
        assert blob["tile"]["device_w"]["drift_nu"] == pol.tile.device_w.drift_nu
        assert policy_from_json(blob) == pol

    params = {"w": jnp.ones((8, 8)), "v": jnp.ones((8, 8))}
    single = _drift_trainer(AnalogPlan.of(("**", pol_pcm)))
    mixed = _drift_trainer(AnalogPlan.of(("w", pol_pcm), ("**", pol_om)))

    # direction 1: single-policy checkpoint -> mixed-plan template
    s_single = single.init(jax.random.PRNGKey(1), params)
    s_single, _ = single.jit_step(donate=False)(s_single, jnp.zeros(()))
    ckpt.save(s_single, str(tmp_path / "a"), step=1)
    template = mixed.init(jax.random.PRNGKey(1), params)
    with pytest.warns(UserWarning, match="om"):   # v really changed policy
        restored = ckpt.restore(template, str(tmp_path / "a"))
    for p in params:
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["W"]),
            np.asarray(s_single["tiles"][p]["W"]), err_msg=p)

    # direction 2: mixed-plan checkpoint -> single-policy template
    s_mixed = mixed.init(jax.random.PRNGKey(2), params)
    s_mixed, _ = mixed.jit_step(donate=False)(s_mixed, jnp.zeros(()))
    ckpt.save(s_mixed, str(tmp_path / "b"), step=1)
    template = single.init(jax.random.PRNGKey(2), params)
    with pytest.warns(UserWarning, match="pcm"):  # v changes policy back
        restored = ckpt.restore(template, str(tmp_path / "b"))
    for p in params:
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["W"]),
            np.asarray(s_mixed["tiles"][p]["W"]), err_msg=p)


def test_trainer_state_roundtrip(tmp_path):
    """Full TrainState (tiles, opt, rng) survives save/restore and resumes."""
    from repro.core.device import DeviceConfig
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.tile import TileConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig

    def loss_fn(params, batch, rng):
        return jnp.sum(params["w"] ** 2), {}

    dev = DeviceConfig(dw_min=0.01, sigma_pm=0.3)
    cfg = TrainerConfig(tile=TileConfig(algorithm="erider", device_p=dev, device_w=dev),
                        digital=DigitalOptConfig(kind="sgdm"),
                        schedule=ScheduleConfig(base_lr=0.1))
    trainer = AnalogTrainer(loss_fn, cfg, analog_filter=lambda p, l: True)
    state = trainer.init(jax.random.PRNGKey(0), {"w": jnp.ones((8, 8))})
    step = trainer.jit_step(donate=False)
    state, _ = step(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)
    restored = ckpt.restore(state, str(tmp_path))
    s2a, _ = step(state, jnp.zeros(()))
    s2b, _ = step(restored, jnp.zeros(()))
    np.testing.assert_allclose(np.asarray(s2a["tiles"]["w"]["W"]),
                               np.asarray(s2b["tiles"]["w"]["W"]))
