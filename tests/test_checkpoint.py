"""Checkpoint tests: roundtrip, async, integrity, restart resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "a": jax.random.normal(k, (17, 33)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": None,
                   "scalar": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, str(tmp_path), step=3)
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored = ckpt.restore(_tree(99), str(tmp_path), verify=True)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(restored["a"]))
    np.testing.assert_array_equal(np.asarray(t["nested"]["b"]),
                                  np.asarray(restored["nested"]["b"]))
    assert restored["nested"]["c"] is None


def test_async_save_and_latest(tmp_path):
    t = _tree()
    th = ckpt.save(t, str(tmp_path), step=1, asynchronous=True)
    th.join(timeout=30)
    ckpt.save(t, str(tmp_path), step=2)
    assert ckpt.latest_step(str(tmp_path)) == 2
    assert os.path.islink(os.path.join(str(tmp_path), "latest"))


def test_restore_shape_mismatch_fails(tmp_path):
    ckpt.save(_tree(), str(tmp_path), step=1)
    bad = {"a": jnp.zeros((5, 5)), "nested": {"b": jnp.zeros(10, jnp.int32),
                                              "c": None, "scalar": jnp.float32(0)}}
    with pytest.raises(AssertionError):
        ckpt.restore(bad, str(tmp_path))


def test_trainer_state_roundtrip(tmp_path):
    """Full TrainState (tiles, opt, rng) survives save/restore and resumes."""
    from repro.core.device import DeviceConfig
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.tile import TileConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig

    def loss_fn(params, batch, rng):
        return jnp.sum(params["w"] ** 2), {}

    dev = DeviceConfig(dw_min=0.01, sigma_pm=0.3)
    cfg = TrainerConfig(tile=TileConfig(algorithm="erider", device_p=dev, device_w=dev),
                        digital=DigitalOptConfig(kind="sgdm"),
                        schedule=ScheduleConfig(base_lr=0.1))
    trainer = AnalogTrainer(loss_fn, cfg, analog_filter=lambda p, l: True)
    state = trainer.init(jax.random.PRNGKey(0), {"w": jnp.ones((8, 8))})
    step = trainer.jit_step(donate=False)
    state, _ = step(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)
    restored = ckpt.restore(state, str(tmp_path))
    s2a, _ = step(state, jnp.zeros(()))
    s2b, _ = step(restored, jnp.zeros(()))
    np.testing.assert_allclose(np.asarray(s2a["tiles"]["w"]["W"]),
                               np.asarray(s2b["tiles"]["w"]["W"]))
