"""Per-architecture smoke tests: reduced configs, fwd/train-step on CPU,
shape checks, no NaNs, prefill/decode consistency with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.lm import LM

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend:
        batch["frames"] = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_grads(arch):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch["tokens"], batch.get("frames"))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, _ = model.loss(params, batch, KEY)
    grads = jax.grad(lambda p: model.loss(p, batch, KEY)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(not bool(jnp.any(jnp.isnan(g))) for g in leaves)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches the full-forward argmax at the
    same position (cache correctness across every layer family)."""
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    tokens = batch["tokens"]

    cache = model.init_cache(B, S + 8, enc_len=S if cfg.is_encdec else 0)
    feed = {"tokens": tokens}
    if cfg.frontend:
        feed["frames"] = batch["frames"]
    lg_pre, cache = jax.jit(model.prefill)(params, feed, cache)

    # full forward logits at the last prompt position must match prefill's
    lg_full, _ = model.forward(params, tokens, batch.get("frames"))
    np.testing.assert_allclose(
        np.asarray(lg_pre[:, -1], np.float32),
        np.asarray(lg_full[:, -1], np.float32), atol=2e-2, rtol=2e-2)

    # one decode step: logits must match a full forward on the extended seq
    nxt = jnp.argmax(lg_pre[:, -1], -1).astype(jnp.int32)[:, None]
    lg_dec, cache = jax.jit(model.decode_step)(params, nxt, cache, jnp.int32(S))
    ext = jnp.concatenate([tokens, nxt], axis=1)
    frames_ext = None
    if cfg.frontend:
        frames_ext = jnp.concatenate(
            [batch["frames"], jnp.zeros((B, 1, cfg.d_model), jnp.float32)], axis=1)
    if cfg.is_encdec:
        # enc-dec decode conditions on the *prefill* encoder output; rebuild
        # the comparison with the same encoder input
        lg_full2, _ = model.forward(params, ext, batch["frames"][:, :S])
    else:
        lg_full2, _ = model.forward(params, ext, frames_ext)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, -1], np.float32),
        np.asarray(lg_full2[:, -1], np.float32), atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b", "deepseek-v2-236b"])
def test_analog_train_step_smoke(arch):
    """One analog E-RIDER train step over a reduced LM: finite loss/metrics."""
    from repro.core.device import DeviceConfig
    from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
    from repro.core.tile import TileConfig
    from repro.core.trainer import AnalogTrainer, TrainerConfig, default_analog_filter

    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    dev = DeviceConfig(dw_min=0.001, sigma_pm=0.3, sigma_d2d=0.1)
    tcfg = TrainerConfig(
        tile=TileConfig(algorithm="erider", device_p=dev, device_w=dev),
        digital=DigitalOptConfig(kind="sgdm"),
        schedule=ScheduleConfig(base_lr=0.05),
        microbatch=2,
    )
    trainer = AnalogTrainer(model.loss, tcfg, default_analog_filter)
    params = model.init(KEY)
    state = trainer.init(jax.random.PRNGKey(1), params)
    assert len(state["tiles"]) > 0, "no analog tiles selected"
    step = trainer.jit_step()
    state, m = step(state, _batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["tile/gp_sq"]))
    state, m2 = step(state, _batch(cfg))
    assert int(state["step"]) == 2
