"""End-to-end behaviour tests for the paper's system.

1. A fully-analog FCN trained with E-RIDER on nonzero-SP devices learns
   (loss drops, accuracy above chance) and tracks the SP.
2. E-RIDER is more robust than TT-v2 under a large reference offset —
   the paper's central Tables 1-2 claim, at smoke scale.
3. The training CLI runs end-to-end with checkpoint/restart.
4. The serving CLI decodes batched requests.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(ROOT, "src")


def test_analog_fcn_learns_and_tracks_sp():
    from benchmarks.common import device_pair, train_image_model

    dev_p, dev_w = device_pair(dw_min=0.02, ref_mean=0.3, ref_std=0.2)
    res = train_image_model(algorithm="erider", dev_p=dev_p, dev_w=dev_w,
                            epochs=1, seed=0)
    assert res.losses[0] > res.losses[-1]
    assert res.test_acc > 0.3, res.test_acc  # 10 classes, chance = 0.1
    assert res.sp_err is not None and res.sp_err < 0.3 ** 2 + 0.2 ** 2


def test_erider_beats_ttv2_under_offset():
    """Tables 1-2 claim, in the discriminating regime: low-state devices
    (~4 conductance states) with a large SP reference offset."""
    from benchmarks.common import device_pair, train_image_model

    dev_p, dev_w = device_pair(dw_min=0.4622, sigma_pm=0.7125,
                               sigma_c2c=0.2174, ref_mean=0.4, ref_std=0.4)
    r_tt = train_image_model(algorithm="ttv2", dev_p=dev_p, dev_w=dev_w,
                             epochs=2, seed=1)
    r_er = train_image_model(algorithm="erider", dev_p=dev_p, dev_w=dev_w,
                             epochs=2, seed=1)
    assert r_er.test_acc > r_tt.test_acc, (r_er.test_acc, r_tt.test_acc)


def _run_cli(args, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-m"] + args, env=env,
                         timeout=timeout, capture_output=True, text=True,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_cli_with_restart(tmp_path):
    ck = str(tmp_path / "ckpt")
    metrics = str(tmp_path / "m.json")
    out = _run_cli(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                    "--steps", "6", "--batch", "4", "--seq", "32",
                    "--ckpt-every", "3", "--ckpt-dir", ck,
                    "--metrics-out", metrics])
    assert "done" in out
    out2 = _run_cli(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
                     "--steps", "8", "--batch", "4", "--seq", "32",
                     "--ckpt-dir", ck])
    assert "restored checkpoint at step 6" in out2


def test_serve_cli():
    out = _run_cli(["repro.launch.serve", "--arch", "qwen2-0.5b", "--smoke",
                    "--requests", "4", "--batch", "2", "--prompt-len", "16",
                    "--gen", "8", "--lanes", "2"])
    assert "tok/s" in out
    assert "continuous" in out


def test_serve_cli_continuous_matches_fixed_from_ckpt(tmp_path):
    """The continuous engine must be token-identical to the fixed-batch
    driver when serving effective analog weights restored from a mixed
    per-path plan checkpoint (attn stacks on RIDER, everything else on
    E-RIDER)."""
    import json

    ck = str(tmp_path / "ckpt")
    algo = "attn=rider,**=erider"
    _run_cli(["repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
              "--steps", "2", "--batch", "2", "--seq", "16",
              "--ckpt-every", "2", "--ckpt-dir", ck, "--algorithm", algo])
    common = ["repro.launch.serve", "--arch", "qwen2-0.5b", "--smoke",
              "--requests", "5", "--prompt-len", "8", "--gen", "6",
              "--gen-spread", "3", "--ckpt-dir", ck, "--algorithm", algo]
    fix = str(tmp_path / "fixed.json")
    con = str(tmp_path / "cont.json")
    man = str(tmp_path / "manifest.json")
    _run_cli(common + ["--engine", "fixed", "--batch", "5",
                       "--dump-tokens", fix])
    _run_cli(common + ["--engine", "continuous", "--lanes", "2",
                       "--dump-tokens", con, "--manifest", man])
    with open(fix) as f1, open(con) as f2:
        fixed, cont = json.load(f1), json.load(f2)
    assert fixed == cont and len(fixed) == 5
    from repro.serving import schema
    with open(man) as f:
        manifest = json.load(f)
    schema.validate_manifest(manifest)
    assert manifest["checkpoint"] == {"restored": True, "dir": ck,
                                      "algorithm": algo}
