"""Run-artifact manifest contract.

The manifest the engine writes at shutdown must validate against the
checked-in ``serving.schema.MANIFEST_SCHEMA``; tampered manifests (missing
fields, wrong enum values, extra keys, wrong schema version) must fail
loudly; and a small real-engine run must leave a valid manifest on disk.
"""
import copy
import json

import jax
import pytest

from repro.serving import schema
from repro.serving.telemetry import Telemetry


_LIFETIME = {
    "age_s": 31557600.0,
    "gdc": True,
    "t0_signature": "checkpoint",
    "drift_scale": {"attn.qkv": {"min": 1.9, "mean": 2.1, "max": 2.4},
                    "mlp.up": {"min": 2.0, "mean": 2.2, "max": 2.3}},
}


def _mini_manifest(tmp_path, log_path="", lifetime=None):
    tel = Telemetry(log_path=log_path)
    tel.request_submitted("r0", 8, 3)
    tel.request_admitted("r0", 0, 1, step=0)
    tel.first_token("r0")
    tel.token("r0")
    tel.token("r0")
    tel.request_finished("r0", 0, step=2)
    tel.steps, tel.prefills = 2, 1
    path = tmp_path / "manifest.json"
    manifest = tel.write_manifest(
        str(path), arch="qwen2-0.5b",
        engine={"mode": "continuous", "lanes": 2, "page_size": 4,
                "num_pages": 9, "table_width": 4},
        checkpoint={"restored": False, "dir": "", "algorithm": ""},
        wall_s=0.25, lifetime=lifetime)
    tel.close()
    return path, manifest


def test_manifest_written_and_valid(tmp_path):
    path, manifest = _mini_manifest(tmp_path)
    on_disk = json.loads(path.read_text())
    assert on_disk == manifest
    schema.validate_manifest(on_disk)
    assert on_disk["workload"] == {"requests": 1, "prompt_tokens": 8,
                                   "generated_tokens": 3}
    assert on_disk["throughput"]["tokens_per_s"] == pytest.approx(3 / 0.25)
    assert on_disk["artifacts"]["log"] is None
    assert on_disk["status"] == "completed"


def test_manifest_records_log_artifact(tmp_path):
    log = tmp_path / "serve_log.jsonl"
    path, manifest = _mini_manifest(tmp_path, log_path=str(log))
    assert manifest["artifacts"]["log"] == str(log)
    assert log.exists()


@pytest.mark.parametrize("mutate, msg", [
    (lambda m: m.pop("latency_s"), "missing required key"),
    (lambda m: m.__setitem__("status", "crashed"), "not in"),
    (lambda m: m.__setitem__("schema_version", 999), "const"),
    (lambda m: m.__setitem__("bonus", 1), "unexpected key"),
    (lambda m: m["engine"].__setitem__("mode", "batched"), "not in"),
    (lambda m: m["engine"].__setitem__("num_pages", 1), "minimum"),
    (lambda m: m["throughput"].__setitem__("wall_s", "fast"), "is not"),
    (lambda m: m["latency_s"]["ttft"].pop("p99"), "missing required key"),
    (lambda m: m["checkpoint"].pop("algorithm"), "missing required key"),
])
def test_tampered_manifest_fails(tmp_path, mutate, msg):
    _, manifest = _mini_manifest(tmp_path)
    bad = copy.deepcopy(manifest)
    mutate(bad)
    with pytest.raises(schema.SchemaError, match=msg):
        schema.validate_manifest(bad)


def test_manifest_lifetime_block_valid(tmp_path):
    """An aged/GDC-corrected serve run records its lifetime provenance."""
    import copy as _copy
    path, manifest = _mini_manifest(tmp_path, lifetime=_copy.deepcopy(_LIFETIME))
    on_disk = json.loads(path.read_text())
    assert on_disk == manifest
    schema.validate_manifest(on_disk)
    assert on_disk["lifetime"]["age_s"] == 31557600.0
    assert on_disk["lifetime"]["t0_signature"] == "checkpoint"
    # absent block stays absent (pre-lifetime manifests unchanged)
    _, plain = _mini_manifest(tmp_path)
    assert "lifetime" not in plain


@pytest.mark.parametrize("mutate, msg", [
    (lambda m: m["lifetime"].pop("age_s"), "missing required key"),
    (lambda m: m["lifetime"].pop("drift_scale"), "missing required key"),
    (lambda m: m["lifetime"].__setitem__("age_s", -1.0), "minimum"),
    (lambda m: m["lifetime"].__setitem__("gdc", "yes"), "is not"),
    (lambda m: m["lifetime"].__setitem__("t0_signature", "guessed"), "not in"),
    (lambda m: m["lifetime"].__setitem__("extra", 1), "unexpected key"),
    (lambda m: m["lifetime"]["drift_scale"]["attn.qkv"].pop("mean"),
     "missing required key"),
    (lambda m: m["lifetime"]["drift_scale"]["attn.qkv"].__setitem__(
        "min", -0.1), "minimum"),
    (lambda m: m["lifetime"]["drift_scale"]["attn.qkv"].__setitem__(
        "p50", 2.0), "unexpected key"),
])
def test_tampered_lifetime_block_fails(tmp_path, mutate, msg):
    _, manifest = _mini_manifest(tmp_path, lifetime=copy.deepcopy(_LIFETIME))
    bad = copy.deepcopy(manifest)
    mutate(bad)
    with pytest.raises(schema.SchemaError, match=msg):
        schema.validate_manifest(bad)


def test_engine_run_writes_manifest_at_shutdown(tmp_path):
    """End to end: a real ServeEngine run leaves a schema-valid manifest and
    log file behind."""
    from repro.configs import get_config
    from repro.models.lm import LM
    from repro.serving import EngineConfig, ServeEngine, ServeRequest
    from repro.launch.serve import build_workload

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    log = tmp_path / "log.jsonl"
    man = tmp_path / "manifest.json"
    ecfg = EngineConfig(lanes=2, page_size=4, num_pages=9, max_len=12,
                        stats_every=2, log_path=str(log),
                        manifest_path=str(man))
    engine = ServeEngine(model, params, ecfg, arch=cfg.name)
    workload = build_workload(cfg, requests=3, prompt_len=6, gen=4)
    results, summary = engine.run(workload)

    assert set(results) == {r.request_id for r in workload}
    assert all(len(v) == 4 for v in results.values())
    manifest = json.loads(man.read_text())
    schema.validate_manifest(manifest)
    assert manifest["arch"] == cfg.name
    assert manifest["engine"]["mode"] == "continuous"
    assert manifest["workload"]["generated_tokens"] == 12
    assert manifest["throughput"]["prefills"] == 3
    assert manifest["artifacts"]["log"] == str(log)
    for line in log.read_text().strip().splitlines():
        schema.validate_log_line(json.loads(line))
