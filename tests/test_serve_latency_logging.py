"""Latency-accounting and structured-logging contracts.

Percentiles must agree with the numpy reference (linear interpolation), the
TTFT/TPOT/e2e math must be exact under a synthetic clock, and every emitted
JSON log line must validate against the checked-in ``serving.schema``.
"""
import io
import json

import numpy as np
import pytest

from repro.serving import schema
from repro.serving.telemetry import (JsonLogger, RequestTimeline, Telemetry,
                                     percentile, summarize)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# percentiles
# ---------------------------------------------------------------------------


def test_percentile_matches_numpy_linear():
    rng = np.random.default_rng(11)
    for n in (1, 2, 3, 7, 50, 257):
        xs = rng.exponential(0.02, size=n).tolist()
        for q in (0.0, 25.0, 50.0, 90.0, 99.0, 100.0):
            ours = percentile(xs, q)
            ref = float(np.percentile(np.asarray(xs), q))
            assert ours == pytest.approx(ref, rel=1e-12, abs=1e-15), (n, q)


def test_percentile_empty_raises():
    with pytest.raises(ValueError):
        percentile([], 50.0)


def test_summarize_fields():
    s = summarize([1.0, 2.0, 3.0, 4.0])
    assert s["p50"] == pytest.approx(2.5)
    assert s["mean"] == pytest.approx(2.5)
    assert s["max"] == 4.0
    assert s["p99"] == pytest.approx(float(np.percentile([1, 2, 3, 4], 99)))


# ---------------------------------------------------------------------------
# timeline math under a synthetic clock
# ---------------------------------------------------------------------------


def test_ttft_tpot_e2e_exact_with_fake_clock():
    clk = FakeClock()
    tel = Telemetry(clock=clk)
    tel.request_submitted("a", 8, 4)
    clk.t = 0.5
    tel.request_admitted("a", 0, 2, step=0)
    clk.t = 0.7
    tel.first_token("a")
    for t in (0.8, 0.9, 1.3):
        clk.t = t
        tel.token("a")
    tel.request_finished("a", 0, step=3)
    tl = tel.timelines["a"]
    assert tl.n_tokens == 4
    assert tl.ttft_s == pytest.approx(0.7)
    assert tl.tpot_s == pytest.approx((1.3 - 0.7) / 3)
    assert tl.e2e_s == pytest.approx(1.3)
    lat = tel.latency_summary()
    assert lat["ttft"]["p50"] == pytest.approx(0.7)
    assert lat["ttft"]["p99"] == pytest.approx(0.7)   # single request


def test_single_token_request_has_zero_tpot():
    tl = RequestTimeline("x", submitted_s=0.0, first_token_s=0.1,
                         finished_s=0.1, n_tokens=1)
    assert tl.tpot_s == 0.0


def test_latency_summary_empty_is_zeros():
    lat = Telemetry(clock=FakeClock()).latency_summary()
    assert lat["tpot"]["p99"] == 0.0


# ---------------------------------------------------------------------------
# structured JSON logging
# ---------------------------------------------------------------------------


def _drive_run(tel):
    tel.request_submitted("r0", 8, 2)
    tel.request_admitted("r0", 0, 1, step=0)
    tel.first_token("r0")
    tel.token("r0")
    tel.request_finished("r0", 0, step=1)
    tel.engine_stats(step=1, active_lanes=0, waiting=0, free_pages=7)
    tel.run_summary(wall_s=0.5)


def test_every_emitted_line_validates_and_round_trips():
    sink = io.StringIO()
    tel = Telemetry(clock=FakeClock(), log_sink=sink)
    _drive_run(tel)
    raw = sink.getvalue().strip().splitlines()
    assert len(raw) == len(tel.logger.lines) == 5
    events = []
    for line in raw:
        obj = json.loads(line)          # one JSON object per line
        schema.validate_log_line(obj)
        events.append(obj["event"])
    assert events == ["request_submitted", "request_admitted",
                      "request_finished", "engine_stats", "run_summary"]


def test_logger_rejects_schema_drift():
    log = JsonLogger()
    with pytest.raises(schema.SchemaError):
        log.emit({"ts": 0.0, "event": "not_an_event"})
    with pytest.raises(schema.SchemaError):            # missing required field
        log.emit({"ts": 0.0, "event": "request_admitted", "request_id": "r",
                  "lane": 0, "step": 0})
    with pytest.raises(schema.SchemaError):            # extra field
        log.emit({"ts": 0.0, "event": "engine_stats", "step": 1,
                  "active_lanes": 0, "waiting": 0, "free_pages": 1,
                  "bonus": True})
    with pytest.raises(schema.SchemaError):            # wrong type
        log.emit({"ts": "zero", "event": "run_summary", "requests": 1,
                  "generated_tokens": 1, "wall_s": 0.1, "tokens_per_s": 10.0})
    assert log.lines == []                             # nothing slipped through


def test_log_path_writes_jsonl_file(tmp_path):
    path = tmp_path / "serve_log.jsonl"
    tel = Telemetry(clock=FakeClock(), log_path=str(path))
    _drive_run(tel)
    tel.close()
    lines = [json.loads(l) for l in path.read_text().strip().splitlines()]
    assert len(lines) == 5
    for obj in lines:
        schema.validate_log_line(obj)
