"""HLO parser/cost-model edge cases + the shared-vocabulary dedupe."""
import warnings

import pytest

from repro.roofline import analysis, hlo_common, hlo_cost

# ---------------------------------------------------------------------------
# shared vocabulary (the dedupe satellite)
# ---------------------------------------------------------------------------


def test_tables_are_shared_not_copied():
    # hlo_cost re-exports the common tables under its legacy names
    assert hlo_cost._DTYPE_BYTES is hlo_common.DTYPE_BYTES
    assert hlo_cost._TRIP_RE is hlo_common.TRIP_RE
    # roofline analysis binds the same objects (its old private copy had
    # drifted: no f8 fnuz variants)
    assert analysis._COLL_RE is hlo_common.COLL_RE
    assert analysis._shape_bytes is hlo_common.shape_bytes


def test_f8_fnuz_variants_present():
    for dt in ("f8e5m2fnuz", "f8e4m3fnuz", "f8e4m3b11fnuz"):
        assert hlo_common.DTYPE_BYTES[dt] == 1
    assert hlo_common.shape_bytes("f8e4m3fnuz[16,4]{1,0}") == 64


def test_zero_width_dtypes():
    assert hlo_common.shape_bytes("token[]") == 0
    b, e = hlo_common.shape_bytes_elems("(f32[4]{0}, token[])")
    assert (b, e) == (16, 5)


# ---------------------------------------------------------------------------
# tuple-shaped results with /*index=N*/ comments
# ---------------------------------------------------------------------------

TUPLE_HLO = """\
HloModule tuple_result

%fused_add (fp: f32[8]) -> (f32[8], f32[8]) {
  %fp = f32[8]{0} parameter(0)
  %x = f32[8]{0} add(f32[8]{0} %fp, f32[8]{0} %fp)
  ROOT %ft = (f32[8]{0} /*index=0*/, f32[8]{0} /*index=1*/) tuple(f32[8]{0} %x, f32[8]{0} %x)
}

ENTRY %main (p0: f32[8]) -> (f32[8], f32[8]) {
  %p0 = f32[8]{0} parameter(0)
  ROOT %f = (f32[8]{0} /*index=0*/, f32[8]{0} /*index=1*/) fusion(f32[8]{0} %p0), kind=kLoop, calls=%fused_add
}
"""


def test_tuple_result_parses_with_index_comments():
    comps = hlo_cost.parse_module(TUPLE_HLO)
    assert set(comps) == {"fused_add", "main"}
    f = comps["main"].instrs[-1]
    assert f.op == "fusion" and f.name == "f"
    assert hlo_common.shape_bytes(f.type_str) == 64
    assert hlo_common.shape_dtypes(f.type_str) == ["f32", "f32"]


def test_tuple_fusion_cost():
    cost = hlo_cost.analyze_hlo(TUPLE_HLO)
    # fusion boundary: 64 B result tuple + 32 B operand; internals free
    assert cost.bytes == 96
    assert cost.flops == 0


# ---------------------------------------------------------------------------
# async -start / -done collective pairs
# ---------------------------------------------------------------------------

ASYNC_COLL_HLO = """\
HloModule async_coll

ENTRY %main (p0: f32[1024]) -> f32[2048] {
  %p0 = f32[1024]{0} parameter(0)
  %ag-start = (f32[1024]{0}, f32[2048]{0}) all-gather-start(f32[1024]{0} %p0), replica_groups={{0,1}}, dimensions={0}
  ROOT %ag-done = f32[2048]{0} all-gather-done((f32[1024]{0}, f32[2048]{0}) %ag-start)
}
"""


def test_async_collective_counted_once():
    cost = hlo_cost.analyze_hlo(ASYNC_COLL_HLO)
    # the -start op carries the collective; -done must not double count
    assert set(cost.coll) == {"all-gather"}
    assert cost.coll_bytes == 4 * (1024 + 2048)


def test_collective_bytes_tolerates_start_suffix_and_tuples():
    out = analysis.collective_bytes(ASYNC_COLL_HLO)
    assert out == {"all-gather": 4 * (1024 + 2048)}


def test_collective_bytes_flat_op():
    hlo = "  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %x), to_apply=%sum\n"
    assert analysis.collective_bytes(hlo) == {"all-reduce": 1024}


# ---------------------------------------------------------------------------
# nested fusion/call computations
# ---------------------------------------------------------------------------

NESTED_HLO = """\
HloModule nested

%inner_dot (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  ROOT %d = f32[8,4]{1,0} dot(f32[8,16]{1,0} %a, f32[16,4]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%outer (x: f32[8,16], y: f32[16,4]) -> f32[8,4] {
  %x = f32[8,16]{1,0} parameter(0)
  %y = f32[16,4]{1,0} parameter(1)
  ROOT %c = f32[8,4]{1,0} call(f32[8,16]{1,0} %x, f32[16,4]{1,0} %y), to_apply=%inner_dot
}

ENTRY %main (p: f32[8,16], q: f32[16,4]) -> f32[8,4] {
  %p = f32[8,16]{1,0} parameter(0)
  %q = f32[16,4]{1,0} parameter(1)
  ROOT %f = f32[8,4]{1,0} fusion(f32[8,16]{1,0} %p, f32[16,4]{1,0} %q), kind=kOutput, calls=%outer
}
"""


def test_nested_fusion_dot_flops_counted():
    cost = hlo_cost.analyze_hlo(NESTED_HLO)
    assert cost.flops == 2 * 8 * 16 * 4


# ---------------------------------------------------------------------------
# while loops: known_trip_count vs unannotated
# ---------------------------------------------------------------------------

def _while_hlo(annot: str) -> str:
    return f"""\
HloModule w

%body (bs: (s32[], f32[64])) -> (s32[], f32[64]) {{
  %bs = (s32[], f32[64]) parameter(0)
  %g = f32[64]{{0}} get-tuple-element((s32[], f32[64]) %bs), index=1
  %h = f32[64]{{0}} add(f32[64]{{0}} %g, f32[64]{{0}} %g)
  %i = s32[] get-tuple-element((s32[], f32[64]) %bs), index=0
  ROOT %bt = (s32[], f32[64]) tuple(s32[] %i, f32[64]{{0}} %h)
}}

%cond (cs: (s32[], f32[64])) -> pred[] {{
  %cs = (s32[], f32[64]) parameter(0)
  %ci = s32[] get-tuple-element((s32[], f32[64]) %cs), index=0
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(s32[] %ci, s32[] %lim), direction=LT
}}

ENTRY %main (p: (s32[], f32[64])) -> (s32[], f32[64]) {{
  %p = (s32[], f32[64]) parameter(0)
  ROOT %w = (s32[], f32[64]) while((s32[], f32[64]) %p), condition=%cond, body=%body{annot}
}}
"""


def test_known_trip_count_scales_body():
    annotated = hlo_cost.analyze_hlo(
        _while_hlo(', backend_config={"known_trip_count":{"n":"10"}}'))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        bare = hlo_cost.analyze_hlo(_while_hlo(""))
    # while op itself moves its carried tuple once in both cases; the
    # body+cond cost scales by the trip count
    carried = 4 + 256
    assert annotated.bytes - carried == 10 * (bare.bytes - carried)


def test_unannotated_while_warns_and_prices_once():
    with pytest.warns(RuntimeWarning, match="known_trip_count"):
        cost = hlo_cost.analyze_hlo(_while_hlo(""))
    assert cost.bytes > 0


# ---------------------------------------------------------------------------
# counted-loop derivation: trips recovered without a known_trip_count annot
# ---------------------------------------------------------------------------

def _counted_while_hlo(init: int, bound: int, step: int,
                       annot: str = "") -> str:
    """Canonical lax.fori_loop lowering: counter in tuple slot 0, constant
    init/bound/step — what derive_trip_count must recover."""
    return f"""\
HloModule cw

%body (bs: (s32[], f32[64])) -> (s32[], f32[64]) {{
  %bs = (s32[], f32[64]) parameter(0)
  %g = f32[64]{{0}} get-tuple-element((s32[], f32[64]) %bs), index=1
  %h = f32[64]{{0}} add(f32[64]{{0}} %g, f32[64]{{0}} %g)
  %i = s32[] get-tuple-element((s32[], f32[64]) %bs), index=0
  %step = s32[] constant({step})
  %ip = s32[] add(s32[] %i, s32[] %step)
  ROOT %bt = (s32[], f32[64]) tuple(s32[] %ip, f32[64]{{0}} %h)
}}

%cond (cs: (s32[], f32[64])) -> pred[] {{
  %cs = (s32[], f32[64]) parameter(0)
  %ci = s32[] get-tuple-element((s32[], f32[64]) %cs), index=0
  %lim = s32[] constant({bound})
  ROOT %lt = pred[] compare(s32[] %ci, s32[] %lim), direction=LT
}}

ENTRY %main (p: f32[64]) -> (s32[], f32[64]) {{
  %p = f32[64]{{0}} parameter(0)
  %c0 = s32[] constant({init})
  %t = (s32[], f32[64]) tuple(s32[] %c0, f32[64]{{0}} %p)
  ROOT %w = (s32[], f32[64]) while((s32[], f32[64]) %t), condition=%cond, body=%body{annot}
}}
"""


def test_counted_loop_derived_without_annotation():
    """A structurally counted loop prices exactly like the same loop with
    the explicit annotation — and emits no RuntimeWarning."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        derived = hlo_cost.analyze_hlo(_counted_while_hlo(0, 10, 1))
    annotated = hlo_cost.analyze_hlo(_counted_while_hlo(
        0, 10, 1, annot=', backend_config={"known_trip_count":{"n":"10"}}'))
    assert derived.bytes == annotated.bytes
    assert derived.flops == annotated.flops


@pytest.mark.parametrize("init, bound, step, trips", [
    (0, 10, 1, 10),
    (0, 10, 3, 4),     # ceil((10-0)/3)
    (2, 10, 2, 4),
    (5, 5, 1, None),   # bound already reached: decline, don't price 0
])
def test_derive_trip_count_arithmetic(init, bound, step, trips):
    comps = hlo_cost.parse_module(_counted_while_hlo(init, bound, step))
    entry = next(c for c in comps.values() if "%main" in c.name
                 or c.name.endswith("main"))
    w = next(i for i in entry.instrs if i.op == "while")
    assert hlo_cost.derive_trip_count(w, entry, comps) == trips


def test_derive_trip_count_rejects_dynamic_loop():
    """The original fixture never advances its counter: not a counted
    loop, so the derivation must decline (and pricing falls back to the
    warned trip-1 path)."""
    comps = hlo_cost.parse_module(_while_hlo(""))
    entry = next(c for c in comps.values() if "main" in c.name)
    w = next(i for i in entry.instrs if i.op == "while")
    assert hlo_cost.derive_trip_count(w, entry, comps) is None


def test_contract_accepts_derived_counted_loop():
    """The graph-contract trip-count rule accepts a derivable loop and
    still rejects a genuinely dynamic one."""
    from repro.analysis.contracts import GraphContract, check_hlo

    contract = GraphContract(name="t", require_donation=False)
    ok = check_hlo(contract, _counted_while_hlo(0, 4, 1))
    assert not [v for v in ok.violations if v["rule"] == "trip-count"]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        bad = check_hlo(contract, _while_hlo(""))
    assert [v for v in bad.violations if v["rule"] == "trip-count"]
