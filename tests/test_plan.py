"""AnalogPlan / TilePolicy tests: per-path policy resolution, the mixed-
policy grouped engine, the legacy (TileConfig, analog_filter) shim, and the
layout-v3 checkpoint manifest (member paths + resolved policies).

Acceptance criteria covered here:
  * one AnalogTrainer trains >= 2 distinct policies (different device
    presets AND algorithms) bit-identically to side-by-side single-policy
    trainers;
  * a legacy single-policy checkpoint restores through the re-key path
    into a mixed-plan template;
  * the legacy constructor still works behind a deprecation warning,
    raised exactly once per process.
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DIGITAL, AnalogPlan, TilePolicy, lm_plan
from repro.checkpoint import ckpt
from repro.core.device import DeviceConfig
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.plan import (_reset_legacy_warning, policy_from_json,
                             policy_to_json)
from repro.core.tile import TileBank, TileConfig, group_tiles
from repro.core.trainer import AnalogTrainer, TrainerConfig

DEV_A = DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1, sigma_c2c=0.05)
DEV_B = DeviceConfig(dw_min=0.02, sigma_pm=0.5, sigma_d2d=0.1, sigma_c2c=0.1,
                     ref_mean=0.1, ref_std=0.1)

# two *distinct* policies: different device presets AND algorithms
POL_A = TilePolicy(TileConfig(algorithm="erider", device_p=DEV_A,
                              device_w=DEV_A, lr_p=0.5, lr_w=0.5, gamma=0.1,
                              eta=0.1, chopper_p=0.1), name="pola")
POL_B = TilePolicy(TileConfig(algorithm="rider", device_p=DEV_B,
                              device_w=DEV_A, lr_p=0.5, lr_w=0.5, gamma=0.1,
                              eta=0.2), name="polb")


def _loss_fn(params, batch, rng):
    # decomposes per leaf: each tile's gradient is independent of which
    # other tiles co-train (the bit-identity tests rely on this)
    return sum(jnp.sum(v ** 2) for _, v in sorted(params.items())), {}


def _trainer(plan: AnalogPlan, **kw) -> AnalogTrainer:
    cfg = TrainerConfig(
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
        **kw,
    )
    return AnalogTrainer(_loss_fn, cfg, plan=plan)


def _mixed_params():
    params = {}
    for i in range(2):
        params[f"a/l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
        params[f"b/l{i}/attn/wq"] = 0.1 * jnp.ones((8, 8))
    return params


MIXED = AnalogPlan.of(("a/**", POL_A), ("b/**", POL_B))


# ---------------------------------------------------------------------------
# rule matching
# ---------------------------------------------------------------------------


def test_plan_first_match_wins_and_pattern_forms():
    plan = AnalogPlan.of(
        ("**/wq", POL_A),                       # glob: ** crosses /
        ("re:attn/(wk|wv)$", POL_B),            # regex (search semantics)
        (lambda p, l: p.endswith("wo"), POL_B),  # predicate
        ("**/wq", POL_B),                       # shadowed: first match wins
        default=DIGITAL,
    )
    leaf = jnp.ones((4, 4))
    assert plan.policy_for("l0/attn/wq", leaf) is POL_A
    assert plan.policy_for("l3/attn/wk", leaf) is POL_B
    assert plan.policy_for("l3/attn/wo", leaf) is POL_B
    assert plan.policy_for("l0/mlp/wi", leaf) is DIGITAL   # default
    # * stays within one path segment
    plan2 = AnalogPlan.of(("*/wq", POL_A))
    assert plan2.policy_for("attn/wq", leaf) is POL_A
    assert plan2.policy_for("l0/attn/wq", leaf) is DIGITAL


def test_plan_min_ndim_keeps_vectors_digital():
    plan = AnalogPlan.of(("**", POL_A))
    assert plan.policy_for("w", jnp.ones((4, 4))) is POL_A
    assert plan.policy_for("bias", jnp.ones((4,))) is DIGITAL
    # analog_min_ndim=0 disables the guard (legacy-shim behavior)
    plan0 = AnalogPlan.of(("**", POL_A), analog_min_ndim=0)
    assert plan0.policy_for("bias", jnp.ones((4,))) is POL_A


def test_lm_plan_keeps_embeddings_digital():
    plan = lm_plan(("**", POL_A))
    leaf = jnp.ones((8, 8))
    assert plan.policy_for("embed/table", leaf) is DIGITAL
    assert plan.policy_for("lm_head/w", leaf) is DIGITAL
    assert plan.policy_for("l0/attn/wq", leaf) is POL_A


# ---------------------------------------------------------------------------
# mixed-policy grouped engine
# ---------------------------------------------------------------------------


def test_mixed_policies_split_groups_and_tag_names():
    params = _mixed_params()
    policies = {p: (POL_A if p.startswith("a/") else POL_B) for p in params}
    index = dict(group_tiles({p: v.shape for p, v in params.items()},
                             TileConfig(), policies))
    assert set(index) == {"g8x8_float32_nM_ppola", "g8x8_float32_nM_ppolb"}
    assert index["g8x8_float32_nM_ppola"] == tuple(
        sorted(p for p in params if p.startswith("a/")))
    # single-policy plans keep the pre-AnalogPlan (untagged) keys
    single = dict(group_tiles({p: v.shape for p, v in params.items()},
                              TileConfig(), {p: POL_A for p in params}))
    assert set(single) == {"g8x8_float32_nM"}


def test_mixed_plan_bit_identical_to_side_by_side_single_policy():
    """Acceptance criterion: a mixed-plan trainer's tiles evolve bit-for-bit
    like the same tiles trained in separate single-policy trainers (per-path
    CRC-keyed RNG, per-leaf-decomposable loss)."""
    params = _mixed_params()

    def run(plan, params, steps=4):
        tr = _trainer(plan)
        state = tr.init(jax.random.PRNGKey(7), params)
        step = tr.jit_step(donate=False)
        for _ in range(steps):
            state, m = step(state, jnp.zeros(()))
        return state

    mixed = run(MIXED, params)
    only_a = run(AnalogPlan.of(("**", POL_A)),
                 {p: v for p, v in params.items() if p.startswith("a/")})
    only_b = run(AnalogPlan.of(("**", POL_B)),
                 {p: v for p, v in params.items() if p.startswith("b/")})

    bank = mixed["tiles"]
    assert isinstance(bank, TileBank)
    assert len(bank.groups) == 2
    for p in params:
        ref = (only_a if p.startswith("a/") else only_b)["tiles"][p]
        assert jax.tree_util.tree_structure(bank[p]) \
            == jax.tree_util.tree_structure(ref), p
        jax.tree.map(
            lambda x, y: np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y), err_msg=p), bank[p], ref)


def test_mixed_plan_scan_matches_unroll_and_aggregates_metrics():
    """Scanned vs unrolled parity holds under a mixed plan too, and metrics
    aggregate the union of the two algorithms' key sets."""
    params = _mixed_params()

    def run(scan):
        tr = _trainer(MIXED, scan_groups=scan)
        state = tr.init(jax.random.PRNGKey(3), params)
        step = tr.jit_step(donate=False)
        for _ in range(3):
            state, m = step(state, jnp.zeros(()))
        return state, m

    s_scan, m_scan = run(True)
    s_unroll, m_unroll = run(False)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        s_scan["tiles"], s_unroll["tiles"])
    assert set(m_scan) == set(m_unroll)
    for k in ("tile/pulses", "tile/sp_err"):
        assert np.isfinite(float(m_scan[k])), k


def test_looped_engine_honors_predicate_rule_policies():
    """The looped engine must use the policy resolved at init (with real
    leaves) — a leaf-dependent predicate rule must neither crash the
    leafless train_step re-resolution nor silently fall back to the
    trainer-default TileConfig."""
    plan = AnalogPlan.of((lambda p, l: l.ndim >= 2, POL_B),
                         analog_min_ndim=0)
    tr = _trainer(plan, engine="looped")
    state = tr.init(jax.random.PRNGKey(2), {"w": 0.1 * jnp.ones((8, 8))})
    # rider tiles have no Qt slot (erider-only) — proves POL_B was used
    assert state["tiles"]["w"].get("Qt") is None
    state, m = tr.jit_step(donate=False)(state, jnp.zeros(()))
    assert np.isfinite(float(m["loss"]))


def test_describe_plan_one_liner():
    tr = _trainer(MIXED)
    line = tr.describe_plan(_mixed_params())
    assert "4 analog paths -> 2 groups" in line
    assert "erider: 2" in line and "rider: 2" in line


# ---------------------------------------------------------------------------
# legacy shim
# ---------------------------------------------------------------------------


def test_legacy_constructor_shim_warns_exactly_once():
    _reset_legacy_warning()
    cfg = TrainerConfig(tile=POL_A.tile,
                        digital=DigitalOptConfig(kind="sgd"),
                        schedule=ScheduleConfig(kind="constant", base_lr=0.1))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        tr = AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)
        AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "AnalogPlan" in str(w.message)]
    assert len(dep) == 1
    # ... and the shimmed trainer still trains (one-rule plan, min_ndim 0)
    state = tr.init(jax.random.PRNGKey(0), {"w": 0.1 * jnp.ones((8, 8))})
    _, m = tr.jit_step(donate=False)(state, jnp.zeros(()))
    assert np.isfinite(float(m["loss"]))


def test_plan_and_filter_are_mutually_exclusive():
    cfg = TrainerConfig(digital=DigitalOptConfig(kind="sgd"),
                        schedule=ScheduleConfig(kind="constant", base_lr=0.1))
    with pytest.raises(ValueError, match="not both"):
        AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True,
                      plan=MIXED)


# ---------------------------------------------------------------------------
# checkpoint layout v3
# ---------------------------------------------------------------------------


def test_manifest_records_members_and_policies(tmp_path):
    tr = _trainer(MIXED)
    state = tr.init(jax.random.PRNGKey(0), _mixed_params())
    ckpt.save(state, str(tmp_path), step=1)
    with open(os.path.join(str(tmp_path), "step_000000001",
                           "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["layout"] == 4
    groups = manifest["tile_groups"]
    bank = state["tiles"]
    assert set(groups) == {g for g, _ in bank.index}
    for g, paths in bank.index:
        assert groups[g]["members"] == list(paths)
        pol = bank.policy(g)
        assert groups[g]["policy"]["tile"]["algorithm"] == pol.tile.algorithm
        assert policy_from_json(groups[g]["policy"]) == pol
    # v4: the class manifest records each class's groups in stack order,
    # with their member paths per slot
    classes = manifest["tile_classes"]
    pidx = dict(bank.index)
    assert set(classes) == {c for c, _ in bank.class_index}
    for c, gnames in bank.class_index:
        assert classes[c]["groups"] == list(gnames)
        assert classes[c]["members"] == [list(pidx[g]) for g in gnames]


def test_policy_json_roundtrip():
    for pol in (POL_A, POL_B, DIGITAL):
        assert policy_from_json(policy_to_json(pol)) == pol


def test_legacy_single_policy_checkpoint_rekeys_into_mixed_plan(tmp_path):
    """Acceptance criterion: a checkpoint written under one global policy
    (untagged group keys, one stack holding all same-shape tiles) restores
    into a mixed-plan template — each policy-tagged group gathers its member
    rows out of the old combined stack."""
    params = _mixed_params()
    single = _trainer(AnalogPlan.of(("**", POL_A)))
    state = single.init(jax.random.PRNGKey(1), params)
    state, _ = single.jit_step(donate=False)(state, jnp.zeros(()))
    assert {g for g, _ in state["tiles"].index} == {"g8x8_float32_nM"}
    ckpt.save(state, str(tmp_path), step=1)

    # POL_A-everywhere checkpoint into a POL_A/POL_B template: the b-group's
    # stored policy differs -> restore warns but re-keys (rider's slot set
    # is a subset of erider's)
    mixed = _trainer(MIXED)
    template = mixed.init(jax.random.PRNGKey(1), params)
    with pytest.warns(UserWarning, match="polb"):
        restored = ckpt.restore(template, str(tmp_path))
    assert {g for g, _ in restored["tiles"].index} \
        == {"g8x8_float32_nM_ppola", "g8x8_float32_nM_ppolb"}
    for p in params:
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["W"]),
            np.asarray(state["tiles"][p]["W"]), err_msg=p)
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["Qd"]),
            np.asarray(state["tiles"][p]["Qd"]), err_msg=p)
    # the re-keyed mixed state steps
    restored2, m = mixed.jit_step(donate=False)(restored, jnp.zeros(()))
    assert np.isfinite(float(m["loss"]))
    assert int(restored2["step"]) == 2


def test_mixed_plan_checkpoint_restores_into_single_policy_template(tmp_path):
    """The reverse re-key: a mixed-plan checkpoint (policy-split stacks)
    restores into a coarser single-policy template by merging the split
    stacks via the v3 member map (with a policy-mismatch warning for the
    tiles that changed policy). The single policy is POL_B (rider), whose
    slot set is a subset of both stored algorithms' — a template needing
    slots an old policy never materialized (e.g. erider's Qt from rider
    tiles) still fails, correctly."""
    params = _mixed_params()
    mixed = _trainer(MIXED)
    state = mixed.init(jax.random.PRNGKey(4), params)
    state, _ = mixed.jit_step(donate=False)(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)

    single = _trainer(AnalogPlan.of(("**", POL_B)))
    template = single.init(jax.random.PRNGKey(4), params)
    assert {g for g, _ in template["tiles"].index} == {"g8x8_float32_nM"}
    with pytest.warns(UserWarning, match="pola"):
        restored = ckpt.restore(template, str(tmp_path))
    for p in params:
        np.testing.assert_array_equal(
            np.asarray(restored["tiles"][p]["W"]),
            np.asarray(state["tiles"][p]["W"]), err_msg=p)
    restored2, m = single.jit_step(donate=False)(restored, jnp.zeros(()))
    assert np.isfinite(float(m["loss"]))
    assert int(restored2["step"]) == 2


def test_mixed_plan_checkpoint_roundtrip(tmp_path):
    tr = _trainer(MIXED)
    state = tr.init(jax.random.PRNGKey(0), _mixed_params())
    step = tr.jit_step(donate=False)
    state, _ = step(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)
    restored = ckpt.restore(state, str(tmp_path), verify=True)
    s2a, _ = step(state, jnp.zeros(()))
    s2b, _ = step(restored, jnp.zeros(()))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        s2a["tiles"], s2b["tiles"])


def test_policy_mismatch_warning_is_consolidated(tmp_path):
    """Restoring a checkpoint whose EVERY stack trained under a different
    policy emits ONE warning naming all mismatched stacks — not one warning
    per stack (large mixed plans would spam hundreds)."""
    params = _mixed_params()
    mixed = _trainer(MIXED)
    state = mixed.init(jax.random.PRNGKey(6), params)
    state, _ = mixed.jit_step(donate=False)(state, jnp.zeros(()))
    ckpt.save(state, str(tmp_path), step=1)

    # retune both policies (same algorithms/slots, new names): every stack
    # in the template now restores under a different policy than it trained
    # with
    pol_a2 = TilePolicy(POL_A.tile, name="tuna")
    pol_b2 = TilePolicy(POL_B.tile, name="tunb")
    retuned = _trainer(AnalogPlan.of(("a/**", pol_a2), ("b/**", pol_b2)))
    template = retuned.init(jax.random.PRNGKey(6), params)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ckpt.restore(template, str(tmp_path))
    pol = [w for w in rec if "policy" in str(w.message)]
    assert len(pol) == 1, [str(w.message) for w in rec]
    msg = str(pol[0].message)
    assert msg.startswith("2 tile stack(s)"), msg
    assert "g8x8_float32_nM_ptuna" in msg, msg
    assert "g8x8_float32_nM_ptunb" in msg, msg
