"""Graph contracts: per-rule units on synthetic HLO, then the real
entrypoints — clean on main, failing under every planted mutation."""
import warnings

import pytest

from repro.analysis import GraphContract, check_hlo
from repro.analysis.contracts import _aliased_outputs, loosened

ALIAS = ("input_output_alias={ {0}: (0, {}, may-alias), "
         "{1}: (1, {}, may-alias) }")


def _mod(body: str, header_extra: str = "") -> str:
    head = "HloModule test" + (", " + header_extra if header_extra else "")
    return (f"{head}\n\nENTRY %main (p0: f32[4]) -> f32[4] {{\n"
            f"  %p0 = f32[4]{{0}} parameter(0)\n{body}}}\n")


def _check(body: str, header_extra: str = ALIAS, **kw):
    kw.setdefault("require_trip_counts", True)
    return check_hlo(GraphContract(name="t", **kw), _mod(body, header_extra))


def _rules(res):
    return sorted({v["rule"] for v in res.violations})


# ---------------------------------------------------------------------------
# per-rule units (pure text -> result; nothing is compiled)
# ---------------------------------------------------------------------------


def test_clean_module_passes():
    res = _check("  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    assert res.ok, res.violations


def test_rank4_concatenate_is_a_restack():
    body = ("  %c = f32[2,3,8,8]{3,2,1,0} concatenate(f32[1,3,8,8]{3,2,1,0} "
            "%p0, f32[1,3,8,8]{3,2,1,0} %p0), dimensions={0}\n"
            "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    assert _rules(_check(body)) == ["restack"]
    # legitimate low-rank concats (grad stacking) don't count
    body3 = body.replace("[2,3,8,8]{3,2,1,0}", "[2,3,8]{2,1,0}") \
                .replace("[1,3,8,8]{3,2,1,0}", "[1,3,8]{2,1,0}")
    assert _check(body3).ok
    # raising max_restacks admits it (and shows up as a loosenable knob)
    assert _check(body, max_restacks=1).ok


def test_missing_alias_header_violates_donation():
    body = "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n"
    res = _check(body, header_extra="")
    assert _rules(res) == ["donation"]
    assert _check(body, header_extra="", require_donation=False).ok


def test_aliased_outputs_counts_entries():
    hlo = _mod("  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n",
               ALIAS + ", entry_computation_layout={(f32[4])->f32[4]}")
    assert _aliased_outputs(hlo) == 2
    assert _aliased_outputs("HloModule bare") == 0


def test_oversized_copy_violates():
    body = ("  %c = f32[1024]{0} copy(f32[1024]{0} %big)\n"
            "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    res = _check(body, max_copy_bytes=1024)
    assert "copy" in _rules(res)
    assert res.stats["max_copy_bytes"] == 4096
    assert _check(body, max_copy_bytes=4096).ok


def test_host_transfer_ops_violate():
    body = ("  %o = token[] outfeed(f32[4]{0} %p0, token[] %tok)\n"
            "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    assert "host-transfer" in _rules(_check(body))


def test_custom_call_needs_allowlist():
    body = ('  %cc = f32[4]{0} custom-call(f32[4]{0} %p0), '
            'custom_call_target="xla_python_cpu_callback"\n'
            "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    assert "host-transfer" in _rules(_check(body))
    assert _check(
        body, allowed_custom_calls=("xla_python_cpu_callback",)).ok


def test_f64_violates_dtype_allowlist():
    body = ("  %d = f64[4]{0} convert(f32[4]{0} %p0)\n"
            "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    res = _check(body)
    assert "dtype" in _rules(res)
    assert "f64" in res.stats["dtypes"]


def test_f64_cannot_be_allowlisted():
    with pytest.raises(ValueError, match="forbidden"):
        GraphContract(name="bad", allowed_dtypes=("f32", "f64"))
    with pytest.raises(ValueError, match="unknown"):
        GraphContract(name="bad", allowed_dtypes=("f32", "float99"))


def test_collective_bytes_ceiling():
    body = ("  %ar = f32[256]{0} all-reduce(f32[256]{0} %p0), to_apply=%sum\n"
            "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n")
    res = _check(body)  # default ceiling is 0
    assert "collective-bytes" in _rules(res)
    assert _check(body, max_collective_bytes=1024.0).ok


def test_hbm_ceiling():
    body = "  ROOT %a = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)\n"
    res = _check(body, max_hbm_bytes=10.0)
    assert _rules(res) == ["hbm-bytes"]


def test_unannotated_while_violates_trip_counts():
    hlo = """\
HloModule w, input_output_alias={ {0}: (0, {}, may-alias) }

%body (bs: (s32[], f32[4])) -> (s32[], f32[4]) {
  %bs = (s32[], f32[4]) parameter(0)
  ROOT %bt = (s32[], f32[4]) copy((s32[], f32[4]) %bs)
}

%cond (cs: (s32[], f32[4])) -> pred[] {
  %cs = (s32[], f32[4]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %w = (s32[], f32[4]) while((s32[], f32[4]) %p), condition=%cond, body=%body
}
"""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = check_hlo(GraphContract(name="t"), hlo)
    assert "trip-count" in _rules(res)
    assert res.stats["whiles_unannotated"] == 1


# ---------------------------------------------------------------------------
# loosening detection (the baseline-drift gate)
# ---------------------------------------------------------------------------


def test_loosened_flags_raised_ceilings_and_grown_allowlists():
    base = GraphContract(name="t", max_hbm_bytes=1e6, max_copy_bytes=1024,
                         allowed_dtypes=("f32", "pred"),
                         min_aliased=4).limits_json()
    same = GraphContract(name="t", max_hbm_bytes=1e6, max_copy_bytes=1024,
                         allowed_dtypes=("f32", "pred"), min_aliased=4)
    assert loosened(same, base) == []

    looser = GraphContract(
        name="t", max_hbm_bytes=2e6, max_copy_bytes=4096,
        allowed_dtypes=("f32", "pred", "bf16"), min_aliased=1,
        require_trip_counts=False, max_restacks=3,
        allowed_custom_calls=("foo",))
    msgs = "\n".join(loosened(looser, base))
    for frag in ("max_hbm_bytes", "max_copy_bytes", "allowed_dtypes",
                 "min_aliased", "require_trip_counts", "max_restacks",
                 "allowed_custom_calls"):
        assert frag in msgs, f"{frag} not flagged:\n{msgs}"
    # tightening is never flagged
    tighter = GraphContract(name="t", max_hbm_bytes=5e5, max_copy_bytes=512,
                            allowed_dtypes=("f32",), min_aliased=8)
    assert loosened(tighter, base) == []


# ---------------------------------------------------------------------------
# the real entrypoints (lower + compile on CPU)
# ---------------------------------------------------------------------------

gc = pytest.importorskip("repro.analysis.graph_contracts")


def test_registry_covers_all_entrypoints():
    assert set(gc.CONTRACTS) == set(gc.ENTRYPOINTS)
    assert len(gc.CONTRACTS) >= 5


@pytest.mark.parametrize("name", sorted(
    ["train_step_fused", "begin_step", "serve_step_lanes"]))
def test_entrypoint_clean_on_main(name):
    res = gc.run_contract(name)
    assert res.ok, res.violations


@pytest.mark.parametrize("mutant, rule", [
    ("restack", "restack"),
    ("host_transfer", "host-transfer"),
    ("f64", "dtype"),
    ("no_donate", "donation"),
])
def test_train_step_mutations_caught(mutant, rule):
    res = gc.run_contract("train_step_fused", mutant=mutant)
    assert not res.ok
    assert rule in _rules(res), (mutant, res.violations)


def test_serve_step_host_transfer_caught():
    res = gc.run_contract("serve_step_lanes", mutant="host_transfer")
    assert not res.ok
    assert "host-transfer" in _rules(res)


def test_serve_step_restack_caught():
    res = gc.run_contract("serve_step_lanes", mutant="restack")
    assert not res.ok
    assert "restack" in _rules(res)
