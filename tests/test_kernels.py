"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.analog_matmul import analog_mvm_pallas
from repro.kernels.analog_update import analog_update_pallas
from repro.kernels.sp_filter import sp_filter_pallas

KEY = jax.random.PRNGKey(0)


def _pad(x, bm, bn, fill=0.0):
    m, n = x.shape
    return jnp.pad(x, ((0, (-m) % bm), (0, (-n) % bn)), constant_values=fill)


@pytest.mark.parametrize("shape", [(8, 128), (256, 512), (300, 700), (512, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_analog_update_matches_ref(shape, dtype):
    ks = jax.random.split(KEY, 6)
    m, n = shape
    w = jax.random.uniform(ks[0], shape, jnp.float32, -0.8, 0.8).astype(dtype)
    dw = (0.05 * jax.random.normal(ks[1], shape)).astype(dtype)
    gamma = jnp.exp(0.1 * jax.random.normal(ks[2], shape))
    rho = 0.3 * jax.random.normal(ks[3], shape)
    ubits = jax.random.bits(ks[4], shape, dtype=jnp.uint32)
    zeta = jax.random.normal(ks[5], shape)
    kw = dict(dw_min=0.01, tau_min=1.0, tau_max=1.0, sigma_c2c=0.1, bl=10)
    bm, bn = min(256, m), min(512, n)
    got = analog_update_pallas(
        _pad(w, bm, bn), _pad(dw, bm, bn), _pad(gamma, bm, bn, 1.0),
        _pad(rho, bm, bn), _pad(ubits, bm, bn).astype(jnp.uint32),
        _pad(zeta, bm, bn), block=(bm, bn), **kw)[:m, :n]
    want = ref.analog_update_ref(w, dw, gamma, rho, ubits, zeta, **kw)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("mkn", [(64, 128, 96), (256, 384, 512), (128, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_analog_mvm_matches_ref(mkn, dtype):
    m, k, n = mkn
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (m, k)).astype(dtype)
    w = (0.1 * jax.random.normal(ks[1], (k, n))).astype(dtype)
    noise = jax.random.normal(ks[2], (m, n))
    io = dict(inp_res=1 / 126, inp_bound=1.0, out_res=1 / 510, out_bound=12.0,
              out_noise=0.06)
    s = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), -1, keepdims=True), 1e-12)
    got = analog_mvm_pallas(x, w, s, noise, blocks=(64, 128, 128), **io)
    # compare against the oracle in f32 (bf16 inputs upcast exactly); the
    # only legitimate difference is K-block accumulation order flipping an
    # ADC LSB -> tolerance = 2 LSB x row scale
    want = ref.analog_mvm_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                              noise, **io)
    tol = float(2 * io["out_res"] * jnp.max(s))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


@pytest.mark.parametrize("shape", [(256, 512), (512, 1024)])
def test_sp_filter_matches_ref(shape):
    ks = jax.random.split(KEY, 4)
    q = 0.1 * jax.random.normal(ks[0], shape)
    p = 0.2 * jax.random.normal(ks[1], shape)
    gamma = jnp.exp(0.1 * jax.random.normal(ks[2], shape))
    rho = 0.3 * jax.random.normal(ks[3], shape)
    got_q, got_g, got_e = sp_filter_pallas(q, p, gamma, rho, eta=0.3,
                                           tau_min=1.0, tau_max=1.0)
    want_q, want_g, want_e = ref.sp_filter_ref(q, p, gamma, rho, eta=0.3,
                                               tau_min=1.0, tau_max=1.0)
    np.testing.assert_allclose(np.asarray(got_q), np.asarray(want_q), atol=1e-6)
    np.testing.assert_allclose(float(got_g), float(want_g), rtol=1e-5)
    np.testing.assert_allclose(float(got_e), float(want_e), rtol=1e-5)


@pytest.mark.parametrize("shape", [(33, 97), (3, 33, 97)])
@pytest.mark.parametrize("rng", ["threefry", "hash"])
def test_ops_backends_bit_identical_on_ragged_shapes(shape, rng):
    """ref and pallas paths must consume identical random bits: noise is
    drawn at the original (non-block-multiple) shape and padded, so both
    backends agree everywhere including the last partial block."""
    from repro.kernels import ops

    ks = jax.random.split(KEY, 3)
    w = jax.random.uniform(ks[0], shape, jnp.float32, -0.8, 0.8)
    dw = 0.05 * jax.random.normal(ks[1], shape)
    gamma = jnp.exp(0.1 * jax.random.normal(ks[2], shape))
    rho = 0.3 * jnp.tanh(jax.random.normal(ks[2], shape))
    kw = dict(dw_min=0.01, tau_min=1.0, tau_max=1.0, sigma_c2c=0.1, bl=10,
              rng=rng)
    try:
        ops.set_backend("ref")
        want = ops.analog_update(w, dw, gamma, rho, KEY, **kw)
        ops.set_backend("pallas")
        got = ops.analog_update(w, dw, gamma, rho, KEY, **kw)
    finally:
        ops.set_backend(None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_ops_mvm_backends_identical_on_ragged_shapes():
    from repro.kernels import ops

    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (5, 33, 47))
    w = 0.1 * jax.random.normal(ks[1], (47, 29))
    io = dict(inp_res=1 / 126, inp_bound=1.0, out_res=1 / 510, out_bound=12.0,
              out_noise=0.06)
    try:
        ops.set_backend("ref")
        want = ops.analog_mvm(x, w, KEY, **io)
        ops.set_backend("pallas")
        got = ops.analog_mvm(x, w, KEY, **io)
    finally:
        ops.set_backend(None)
    tol = 2 * io["out_res"] * float(jnp.max(jnp.abs(x)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


@pytest.mark.parametrize("preset", ["reram_hfo2", "reram_om",
                                    "softbounds_2000", "ecram", "ideal"])
def test_analog_update_pallas_matches_fused_generic(preset):
    """The Pallas kernel's inline softbounds response must agree with the
    generic jnp oracle (``pulse._fused_generic``) for every named device
    preset — same injected (ubits, zeta) noise, so any drift is math, not
    RNG. |w| stays inside 0.8x the device range to keep the oracle's
    positive-definiteness clip (responses() eps floor) inactive; outside it
    the kernel intentionally skips the clip (TPU fast path)."""
    from repro.core import device, pulse

    cfg = device.PRESETS[preset]
    shape = (256, 512)
    ks = jax.random.split(KEY, 5)
    lim = 0.8 * min(cfg.tau_min, cfg.tau_max)
    w = jax.random.uniform(ks[0], shape, jnp.float32, -lim, lim)
    dw = 3.0 * cfg.dw_min * jax.random.normal(ks[1], shape)
    dp = device.sample_device(ks[2], shape, cfg)
    ubits = jax.random.bits(ks[3], shape, dtype=jnp.uint32)
    zeta = jax.random.normal(ks[4], shape)
    got = analog_update_pallas(
        w, dw, dp["gamma"], dp["rho"], ubits, zeta,
        dw_min=cfg.dw_min, tau_min=cfg.tau_min, tau_max=cfg.tau_max,
        sigma_c2c=cfg.sigma_c2c, bl=10)
    want = pulse._fused_generic(w, dw, dp, cfg, None, bl=10,
                                noise=(ubits, zeta))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_analog_update_pallas_batched_stack_matches_per_tile():
    """The 3-D (stack, m, n) kernel form — one grid axis per class member —
    must be bitwise the per-member 2-D kernel: the grouped engine's fused
    backend relies on this to process a whole TileBank class in one call."""
    ks = jax.random.split(KEY, 6)
    shape = (3, 64, 128)
    w = jax.random.uniform(ks[0], shape, jnp.float32, -0.8, 0.8)
    dw = 0.05 * jax.random.normal(ks[1], shape)
    gamma = jnp.exp(0.1 * jax.random.normal(ks[2], shape))
    rho = 0.3 * jax.random.normal(ks[3], shape)
    ubits = jax.random.bits(ks[4], shape, dtype=jnp.uint32)
    zeta = jax.random.normal(ks[5], shape)
    kw = dict(dw_min=0.01, tau_min=1.0, tau_max=1.0, sigma_c2c=0.1, bl=10,
              block=(64, 128))
    got = analog_update_pallas(w, dw, gamma, rho, ubits, zeta, **kw)
    for i in range(shape[0]):
        want_i = analog_update_pallas(w[i], dw[i], gamma[i], rho[i],
                                      ubits[i], zeta[i], **kw)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want_i),
                                      err_msg=f"member {i}")


def test_hash_normal_finite_at_lattice_edges(monkeypatch):
    """Regression: the inverse-CDF transform must stay finite at the ends of
    the uint32 lattice. Without the clip in hash_normal, bits near 0 and
    2^32-1 round |2u-1| to exactly 1.0f and erfinv returns +-inf — one such
    draw (~1e-7 probability per element) NaN-poisons W through the pulse
    update."""
    from repro.kernels import fastrng

    edge = jnp.array([0, 1, 2 ** 31 - 1, 2 ** 31, 2 ** 32 - 2, 2 ** 32 - 1],
                     dtype=jnp.uint32)
    monkeypatch.setattr(fastrng, "hash_bits", lambda seed, shape, salt: edge)
    z = np.asarray(fastrng.hash_normal(jnp.zeros(2, jnp.uint32),
                                       edge.shape, 0))
    assert np.all(np.isfinite(z)), z
    # the clip caps samples at ~5.4 sigma; the ends are symmetric
    assert np.all(np.abs(z) < 6.0), z
    np.testing.assert_allclose(z[0], -z[-1], rtol=1e-5)
    assert z[0] < -3.0 and z[-1] > 3.0, z


def test_hash_normal_matches_exact_inverse_cdf(monkeypatch):
    """hash_normal's fast erfinv (bitcast log + Giles polynomials) tracks
    the exact inverse CDF to well inside the f32 noise floor of the pulse
    math that consumes it."""
    from repro.kernels import fastrng

    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.integers(0, 2 ** 32, size=1 << 16,
                                    dtype=np.uint32))
    monkeypatch.setattr(fastrng, "hash_bits", lambda seed, shape, salt: bits)
    got = np.asarray(fastrng.hash_normal(jnp.zeros(2, jnp.uint32),
                                         bits.shape, 0))
    u = (bits.astype(jnp.float32) + 0.5) * (1.0 / 4294967296.0)
    x = jnp.clip(2.0 * u - 1.0, -fastrng._ONE_MINUS_EPS,
                 fastrng._ONE_MINUS_EPS)
    exact = np.asarray(fastrng._SQRT2 * jax.lax.erf_inv(x.astype(jnp.float64)),
                       np.float64)
    err = np.abs(got - exact)
    assert err.mean() < 1e-4, err.mean()
    assert err.max() < 0.02, err.max()  # worst case sits in the clamped tail


def test_ops_wrappers_arbitrary_rank():
    """ops.* accept >2-D and 1-D inputs (reshape/pad handled)."""
    from repro.kernels import ops

    w = jax.random.uniform(KEY, (3, 40, 50), jnp.float32, -0.5, 0.5)
    out = ops.analog_update(
        w, 0.01 * jnp.ones_like(w), jnp.ones_like(w), jnp.zeros_like(w),
        KEY, dw_min=0.01, tau_min=1.0, tau_max=1.0, sigma_c2c=0.0)
    assert out.shape == w.shape
    x = jax.random.normal(KEY, (2, 5, 48))
    wmat = jax.random.normal(KEY, (48, 32)) * 0.1
    y = ops.analog_mvm(x, wmat, KEY, inp_res=1 / 126, inp_bound=1.0,
                       out_res=1 / 510, out_bound=12.0, out_noise=0.0)
    assert y.shape == (2, 5, 32)
