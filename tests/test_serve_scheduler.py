"""Continuous-batching scheduler + page-allocator contracts.

Admission is strict FIFO into freed decode lanes (a freed lane admits the
*oldest* waiting prefill next step; head-of-line page budgeting means no
request starves behind smaller ones), and the page allocator never leaks or
double-frees pages across arbitrary request arrival/finish sequences.
"""
import numpy as np
import pytest

from repro.serving.kv_pages import PageAllocator, SCRATCH_PAGE, flat_slots, needed_pages
from repro.serving.scheduler import ContinuousScheduler, ServeRequest


def _req(i, prompt_len=8, max_new=8, arrival=0):
    return ServeRequest(request_id=f"r{i}", prompt=np.zeros(prompt_len, np.int32),
                        max_new_tokens=max_new, arrival_step=arrival)


def _sched(lanes=2, num_pages=64, page_size=4, table_width=8):
    alloc = PageAllocator(num_pages, reserved=1)
    return ContinuousScheduler(lanes, alloc, page_size, table_width), alloc


# ---------------------------------------------------------------------------
# admission order / lane reuse
# ---------------------------------------------------------------------------


def test_freed_lane_admits_oldest_waiting_next_step():
    sched, _ = _sched(lanes=2)
    reqs = [_req(i) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    adm = sched.admit(step=0)
    assert [a.request.request_id for a in adm] == ["r0", "r1"]
    assert sched.admit(step=1) == []          # lanes full
    freed_lane = adm[1].lane
    sched.release(freed_lane)
    nxt = sched.admit(step=2)
    assert [a.request.request_id for a in nxt] == ["r2"]   # oldest waiting
    assert nxt[0].lane == freed_lane                        # reuses the lane


def test_arrival_step_gates_admission():
    sched, _ = _sched(lanes=4)
    sched.submit(_req(0, arrival=3))
    assert sched.admit(step=0) == []
    assert sched.admit(step=2) == []
    assert [a.request.request_id for a in sched.admit(step=3)] == ["r0"]


def test_no_starvation_head_of_line_page_budget():
    """A big request at the queue head blocks later small ones (FIFO), then
    admits as soon as pages free — it is never skipped."""
    # pool: 7 usable pages, page_size 4
    sched, alloc = _sched(lanes=3, num_pages=8, page_size=4, table_width=8)
    sched.submit(_req(0, prompt_len=8, max_new=8))    # 4 pages
    sched.submit(_req(1, prompt_len=8, max_new=8))    # 4 pages -> won't fit
    sched.submit(_req(2, prompt_len=4, max_new=4))    # 2 pages, younger
    adm = sched.admit(step=0)
    assert [a.request.request_id for a in adm] == ["r0"]
    assert sched.n_waiting == 2                        # r2 did NOT skip r1
    sched.release(adm[0].lane)
    order = [a.request.request_id for a in sched.admit(step=1)]
    assert order == ["r1", "r2"]


def test_fifo_admission_under_random_finish_order():
    rng = np.random.default_rng(0)
    sched, alloc = _sched(lanes=3, num_pages=32, page_size=4, table_width=8)
    n = 20
    for i in range(n):
        sched.submit(_req(i, prompt_len=4, max_new=int(rng.integers(1, 12))))
    admitted = []
    step = 0
    while sched.has_work():
        admitted += [a.request.request_id for a in sched.admit(step)]
        active = list(sched.active())
        if active:  # finish a random active lane
            sched.release(active[int(rng.integers(len(active)))])
        step += 1
        assert step < 10_000
    assert admitted == [f"r{i}" for i in range(n)]     # strict FIFO, none starved
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity


def test_submit_rejects_oversized_requests():
    sched, _ = _sched(lanes=2, num_pages=8, page_size=4, table_width=4)
    with pytest.raises(ValueError):
        sched.submit(_req(0, prompt_len=16, max_new=16))  # > table width
    sched2, _ = _sched(lanes=2, num_pages=4, page_size=4, table_width=16)
    with pytest.raises(ValueError):
        sched2.submit(_req(1, prompt_len=32, max_new=32))  # > pool capacity


def test_table_row_scratch_padding_and_flat_slots():
    sched, _ = _sched(lanes=1, page_size=4, table_width=8)
    r = _req(0, prompt_len=6, max_new=3)               # 9 tokens -> 3 pages
    sched.submit(r)
    [adm] = sched.admit(0)
    row = sched.table_row(r)
    assert row.shape == (8,)
    assert list(row[:3]) == adm.pages
    assert all(p == SCRATCH_PAGE for p in row[3:])
    assert SCRATCH_PAGE not in adm.pages
    slots = flat_slots(list(row), 4, 9)
    assert len(set(slots)) == 9                        # injective
    assert slots[:4] == [adm.pages[0] * 4 + j for j in range(4)]


# ---------------------------------------------------------------------------
# allocator: no leaks, no double frees
# ---------------------------------------------------------------------------


def _run_alloc_trace(num_pages, trace):
    """trace: sequence of ('alloc', n) / ('free', idx). Checks invariants
    after every op; returns number of successful allocations."""
    alloc = PageAllocator(num_pages, reserved=1)
    live = {}
    n_ok = 0
    for op, arg in trace:
        if op == "alloc":
            owner = object()
            pages = alloc.alloc(arg, owner)
            if arg > alloc.capacity - sum(len(p) for p, _ in live.values()):
                assert pages is None
            if pages is not None:
                assert len(pages) == arg
                for existing, _ in live.values():
                    assert not set(pages) & set(existing)
                live[n_ok] = (pages, owner)
                n_ok += 1
        elif live:
            key = sorted(live)[arg % len(live)]
            pages, owner = live.pop(key)
            alloc.free(pages, owner)
            if pages:
                with pytest.raises(ValueError):
                    alloc.free(pages, owner)           # double free raises
        alloc.check_consistent()
    for pages, owner in live.values():
        alloc.free(pages, owner)
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity          # nothing leaked
    return n_ok


def test_allocator_never_leaks_random_sequences():
    rng = np.random.default_rng(7)
    for _ in range(50):
        trace = [("alloc" if rng.random() < 0.6 else "free",
                  int(rng.integers(0, 9))) for _ in range(60)]
        _run_alloc_trace(int(rng.integers(4, 33)), trace)


def test_allocator_property_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                             st.integers(0, 8)), max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(3, 40), ops)
    def prop(num_pages, trace):
        _run_alloc_trace(num_pages, trace)

    prop()


def test_needed_pages():
    assert needed_pages(1, 4) == 1
    assert needed_pages(4, 4) == 1
    assert needed_pages(5, 4) == 2
    assert needed_pages(64, 16) == 4
