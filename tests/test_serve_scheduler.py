"""Continuous-batching scheduler + page-allocator contracts.

Admission is strict FIFO into freed decode lanes (a freed lane admits the
*oldest* waiting prefill next step; head-of-line page budgeting means no
request starves behind smaller ones), and the page allocator never leaks or
double-frees pages across arbitrary request arrival/finish sequences.
"""
import numpy as np
import pytest

from repro.serving.kv_pages import (PageAllocator, PrefixCache, SCRATCH_PAGE,
                                    flat_slots, needed_pages)
from repro.serving.scheduler import ContinuousScheduler, ServeRequest


def _req(i, prompt_len=8, max_new=8, arrival=0):
    return ServeRequest(request_id=f"r{i}", prompt=np.zeros(prompt_len, np.int32),
                        max_new_tokens=max_new, arrival_step=arrival)


def _sched(lanes=2, num_pages=64, page_size=4, table_width=8):
    alloc = PageAllocator(num_pages, reserved=1)
    return ContinuousScheduler(lanes, alloc, page_size, table_width), alloc


# ---------------------------------------------------------------------------
# admission order / lane reuse
# ---------------------------------------------------------------------------


def test_freed_lane_admits_oldest_waiting_next_step():
    sched, _ = _sched(lanes=2)
    reqs = [_req(i) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    adm = sched.admit(step=0)
    assert [a.request.request_id for a in adm] == ["r0", "r1"]
    assert sched.admit(step=1) == []          # lanes full
    freed_lane = adm[1].lane
    sched.release(freed_lane)
    nxt = sched.admit(step=2)
    assert [a.request.request_id for a in nxt] == ["r2"]   # oldest waiting
    assert nxt[0].lane == freed_lane                        # reuses the lane


def test_arrival_step_gates_admission():
    sched, _ = _sched(lanes=4)
    sched.submit(_req(0, arrival=3))
    assert sched.admit(step=0) == []
    assert sched.admit(step=2) == []
    assert [a.request.request_id for a in sched.admit(step=3)] == ["r0"]


def test_no_starvation_head_of_line_page_budget():
    """A big request at the queue head blocks later small ones (FIFO), then
    admits as soon as pages free — it is never skipped."""
    # pool: 7 usable pages, page_size 4
    sched, alloc = _sched(lanes=3, num_pages=8, page_size=4, table_width=8)
    sched.submit(_req(0, prompt_len=8, max_new=8))    # 4 pages
    sched.submit(_req(1, prompt_len=8, max_new=8))    # 4 pages -> won't fit
    sched.submit(_req(2, prompt_len=4, max_new=4))    # 2 pages, younger
    adm = sched.admit(step=0)
    assert [a.request.request_id for a in adm] == ["r0"]
    assert sched.n_waiting == 2                        # r2 did NOT skip r1
    sched.release(adm[0].lane)
    order = [a.request.request_id for a in sched.admit(step=1)]
    assert order == ["r1", "r2"]


def test_fifo_admission_under_random_finish_order():
    rng = np.random.default_rng(0)
    sched, alloc = _sched(lanes=3, num_pages=32, page_size=4, table_width=8)
    n = 20
    for i in range(n):
        sched.submit(_req(i, prompt_len=4, max_new=int(rng.integers(1, 12))))
    admitted = []
    step = 0
    while sched.has_work():
        admitted += [a.request.request_id for a in sched.admit(step)]
        active = list(sched.active())
        if active:  # finish a random active lane
            sched.release(active[int(rng.integers(len(active)))])
        step += 1
        assert step < 10_000
    assert admitted == [f"r{i}" for i in range(n)]     # strict FIFO, none starved
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity


def test_submit_rejects_oversized_requests():
    sched, _ = _sched(lanes=2, num_pages=8, page_size=4, table_width=4)
    with pytest.raises(ValueError):
        sched.submit(_req(0, prompt_len=16, max_new=16))  # > table width
    sched2, _ = _sched(lanes=2, num_pages=4, page_size=4, table_width=16)
    with pytest.raises(ValueError):
        sched2.submit(_req(1, prompt_len=32, max_new=32))  # > pool capacity


def test_table_row_scratch_padding_and_flat_slots():
    sched, _ = _sched(lanes=1, page_size=4, table_width=8)
    r = _req(0, prompt_len=6, max_new=3)               # 9 tokens -> 3 pages
    sched.submit(r)
    [adm] = sched.admit(0)
    row = sched.table_row(r)
    assert row.shape == (8,)
    assert list(row[:3]) == adm.pages
    assert all(p == SCRATCH_PAGE for p in row[3:])
    assert SCRATCH_PAGE not in adm.pages
    slots = flat_slots(list(row), 4, 9)
    assert len(set(slots)) == 9                        # injective
    assert slots[:4] == [adm.pages[0] * 4 + j for j in range(4)]


# ---------------------------------------------------------------------------
# allocator: no leaks, no double frees
# ---------------------------------------------------------------------------


def _run_alloc_trace(num_pages, trace):
    """trace: sequence of ('alloc', n) / ('free', idx). Checks invariants
    after every op; returns number of successful allocations."""
    alloc = PageAllocator(num_pages, reserved=1)
    live = {}
    n_ok = 0
    for op, arg in trace:
        if op == "alloc":
            owner = object()
            pages = alloc.alloc(arg, owner)
            if arg > alloc.capacity - sum(len(p) for p, _ in live.values()):
                assert pages is None
            if pages is not None:
                assert len(pages) == arg
                for existing, _ in live.values():
                    assert not set(pages) & set(existing)
                live[n_ok] = (pages, owner)
                n_ok += 1
        elif live:
            key = sorted(live)[arg % len(live)]
            pages, owner = live.pop(key)
            alloc.free(pages, owner)
            if pages:
                with pytest.raises(ValueError):
                    alloc.free(pages, owner)           # double free raises
        alloc.check_consistent()
    for pages, owner in live.values():
        alloc.free(pages, owner)
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity          # nothing leaked
    return n_ok


def test_allocator_never_leaks_random_sequences():
    rng = np.random.default_rng(7)
    for _ in range(50):
        trace = [("alloc" if rng.random() < 0.6 else "free",
                  int(rng.integers(0, 9))) for _ in range(60)]
        _run_alloc_trace(int(rng.integers(4, 33)), trace)


def test_allocator_property_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                             st.integers(0, 8)), max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(3, 40), ops)
    def prop(num_pages, trace):
        _run_alloc_trace(num_pages, trace)

    prop()


# ---------------------------------------------------------------------------
# allocator: copy-on-write refcounts
# ---------------------------------------------------------------------------


def test_shared_page_freed_only_at_last_ref():
    alloc = PageAllocator(8, reserved=1)
    a, b, cache = "reqA", "reqB", "cache"
    pages = alloc.alloc(3, a)
    alloc.share(pages[:2], b)
    alloc.share(pages[:1], cache)
    assert [alloc.refcount(p) for p in pages] == [3, 2, 1]
    alloc.release(pages, a)                 # b/cache refs keep pages 0 and 1
    assert alloc.free_pages == alloc.capacity - 2
    with pytest.raises(ValueError):
        alloc.release(pages, a)             # a's refs are already gone
    alloc.release(pages[:2], b)
    assert alloc.free_pages == alloc.capacity - 1
    alloc.release(pages[:1], cache)
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity


def test_share_free_page_raises_without_mutation():
    alloc = PageAllocator(8, reserved=1)
    pages = alloc.alloc(2, "req")
    free_page = next(p for p in range(1, 8) if p not in pages)
    with pytest.raises(ValueError):
        alloc.share(pages + [free_page], "other")
    # all-or-nothing: the valid pages gained no partial ref
    assert [alloc.refcount(p) for p in pages] == [1, 1]
    alloc.check_consistent()


def _run_share_trace(num_pages, trace):
    """trace: ('alloc', n) / ('share', idx) / ('release', idx).  Mirrors the
    allocator against a host-side refcount model, checked after every op;
    releasing an owner's refs twice must raise and change nothing."""
    alloc = PageAllocator(num_pages, reserved=1)
    holders = []                    # (pages, owner) — one ref per entry
    model = {}                      # page -> expected refcount
    serial = 0
    for op, arg in trace:
        if op == "alloc":
            owner = ("own", serial)
            serial += 1
            pages = alloc.alloc(arg, owner)
            if pages is None:
                assert arg > alloc.capacity - len(model)
            else:
                assert len(pages) == arg and not set(pages) & set(model)
                holders.append((pages, owner))
                for p in pages:
                    model[p] = 1
        elif op == "share" and holders:
            src_pages, _ = holders[arg % len(holders)]
            take = src_pages[:1 + arg % max(1, len(src_pages))]
            owner = ("share", serial)
            serial += 1
            alloc.share(take, owner)
            holders.append((take, owner))
            for p in take:
                model[p] += 1
        elif op == "release" and holders:
            pages, owner = holders.pop(arg % len(holders))
            alloc.release(pages, owner)
            if pages:
                with pytest.raises(ValueError):
                    alloc.release(pages, owner)
            for p in pages:
                model[p] -= 1
                assert model[p] >= 0
                if model[p] == 0:
                    del model[p]
        for p, n in model.items():
            assert alloc.refcount(p) == n
        assert alloc.free_pages == alloc.capacity - len(model)
        alloc.check_consistent()
    for pages, owner in holders:
        alloc.release(pages, owner)
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity


def test_allocator_cow_never_leaks_random_sequences():
    rng = np.random.default_rng(11)
    kinds = ["alloc", "share", "release"]
    for _ in range(50):
        trace = [(kinds[int(rng.integers(3))], int(rng.integers(0, 9)))
                 for _ in range(60)]
        _run_share_trace(int(rng.integers(4, 33)), trace)


def test_allocator_cow_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(st.sampled_from(["alloc", "share", "release"]),
                             st.integers(0, 8)), max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(st.integers(3, 40), ops)
    def prop(num_pages, trace):
        _run_share_trace(num_pages, trace)

    prop()


# ---------------------------------------------------------------------------
# prefix cache + prefix-aware submit budgeting
# ---------------------------------------------------------------------------


def test_prefix_cache_publish_probe_release_cycle():
    alloc = PageAllocator(16, reserved=1)
    cache = PrefixCache(alloc, page_size=4)
    prompt = np.arange(13, dtype=np.int32)          # 3 full pages + tail
    pages = alloc.alloc(4, "pub")
    assert cache.publish(prompt, pages, 3) == 3
    assert cache.probe(prompt, 3) == pages[:3]
    # chained keys commit to *prefixes*: diverging page 2 stops the run
    fork = prompt.copy()
    fork[9] += 1
    assert cache.probe(fork, 3) == pages[:2]
    got = cache.acquire(prompt, 3, "holder")
    assert got == pages[:3]
    alloc.release(pages, "pub")                     # publisher finishes...
    assert [alloc.refcount(p) for p in pages[:3]] == [2, 2, 2]  # cache+holder
    alloc.release(got, "holder")
    cache.clear()                                   # cascades + drops cache refs
    assert len(cache) == 0
    cache.check_consistent()
    alloc.check_consistent()
    assert alloc.free_pages == alloc.capacity


def test_submit_budgets_prefix_shared_pages():
    """A request whose *full* footprint exceeds the pool must still be
    accepted when cached prefix pages cover the overshoot, and rejections
    must name the prefix-shared page count."""
    alloc = PageAllocator(8, reserved=1)            # 7 usable pages
    cache = PrefixCache(alloc, page_size=4)
    sched = ContinuousScheduler(2, alloc, page_size=4, table_width=16,
                                prefix_cache=cache)
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, 100, size=16).astype(np.int32)
    pub = ServeRequest("pub", prompt, max_new_tokens=4)
    sched.submit(pub)
    [adm] = sched.admit(0)
    sched.publish_prefix(pub)                       # 4 prompt pages cached
    sched.release(adm.lane)
    # 32 tokens -> 8 pages > 7-page pool, but 3 leading pages probe shared
    sched.submit(ServeRequest("big", prompt, max_new_tokens=16))
    assert sched.n_waiting == 1
    # same size, cold prompt: rejected, message names the zero share count
    with pytest.raises(ValueError, match=r"needs 8 pages \(0 prefix-shared\), "
                                         r"pool has 7"):
        sched.submit(ServeRequest("cold", prompt[::-1].copy(),
                                  max_new_tokens=16))
    # shared prefix but a tail the pool can never hold
    with pytest.raises(ValueError, match=r"needs 11 pages \(3 prefix-shared\)"):
        sched.submit(ServeRequest("huge", prompt, max_new_tokens=28))


def test_needed_pages():
    assert needed_pages(1, 4) == 1
    assert needed_pages(4, 4) == 1
    assert needed_pages(5, 4) == 2
    assert needed_pages(64, 16) == 4
