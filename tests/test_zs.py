"""Zero-shifting (Algorithm 1) convergence tests against Thm 2.2 / C.2."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import device, zs


def _setup(dw_min=0.01, key=0):
    cfg = device.DeviceConfig(dw_min=dw_min, sigma_pm=0.4, sigma_d2d=0.1)
    dp = device.sample_device(jax.random.PRNGKey(key), (48, 48), cfg)
    return cfg, dp, device.symmetric_point(dp, cfg)


@pytest.mark.parametrize("scheme", ["stochastic", "cyclic"])
def test_zs_converges(scheme):
    cfg, dp, sp = _setup()
    w = zs.zs_estimate(jax.random.PRNGKey(1), jnp.zeros((48, 48)), dp, cfg,
                       2000, scheme=scheme)
    rmse = float(jnp.sqrt(jnp.mean((w - sp) ** 2)))
    assert rmse < 0.1 * float(jnp.std(sp)) + 0.02, rmse


def test_zs_error_floor_scales_with_dwmin():
    """Thm 2.2: the achievable error floor is Theta(dw_min)."""
    floors = []
    for dw_min in (0.04, 0.01):
        cfg, dp, sp = _setup(dw_min)
        w = zs.zs_estimate(jax.random.PRNGKey(2), jnp.zeros((48, 48)), dp, cfg,
                           int(40 / dw_min))
        floors.append(float(jnp.mean(jnp.abs(w - sp))))
    assert floors[1] < floors[0], floors  # finer device -> lower floor


def test_zs_trace_g_decreases():
    cfg, dp, sp = _setup()
    _, trace = zs.zs_estimate_with_trace(jax.random.PRNGKey(3),
                                         jnp.zeros((48, 48)), dp, cfg, 1500)
    g = trace["g_sq"]
    assert float(g[-1]) < 0.2 * float(g[0])
    n = zs.pulses_to_target(g, float(g[0]) * 0.5)
    assert 0 < n <= 1500
