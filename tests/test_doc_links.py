"""check_doc_links: GitHub slugging and anchor validation."""
import importlib.util
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

spec = importlib.util.spec_from_file_location(
    "check_doc_links", os.path.join(REPO, "tools", "check_doc_links.py"))
cdl = importlib.util.module_from_spec(spec)
spec.loader.exec_module(cdl)


def test_github_slug_rules():
    assert cdl.github_slug("Graph contracts") == "graph-contracts"
    assert cdl.github_slug("The grouped train step (`core/trainer.py`)") \
        == "the-grouped-train-step-coretrainerpy"
    assert cdl.github_slug("Policy- and spec-aware keys") \
        == "policy--and-spec-aware-keys"
    assert cdl.github_slug("[linked](docs/x.md) header") == "linked-header"


def test_anchors_dedupe_and_skip_fences(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text("# Top\n## Same\n## Same\n```\n# not a header\n```\n")
    assert cdl.anchors_of(str(md)) == {"top", "same", "same-1"}


def test_broken_anchor_reported(tmp_path):
    target = tmp_path / "target.md"
    target.write_text("# Real Section\n")
    src = tmp_path / "src.md"
    src.write_text("[ok](target.md#real-section) [bad](target.md#gone) "
                   "[self](#missing)\n")
    broken = cdl.check_file(str(src))
    assert [(t, w) for t, _, w in broken] == [
        ("target.md#gone", "has no section anchor #gone"),
        ("#missing", "has no section anchor #missing"),
    ]


def test_repo_docs_pass():
    bad = []
    for md in cdl.doc_files():
        bad.extend(cdl.check_file(md))
    assert bad == []
