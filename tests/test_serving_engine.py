"""Continuous-batching engine vs the fixed-batch reference.

The engines must be token-identical: prefill reuses the dense path, the
paged commit/gather preserves logical KV order, and per-lane masking matches
the lockstep decode.  Also covers the factored-out sampling/feed helpers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import LM
from repro.serving import EngineConfig, FeedBuilder, ServeEngine, sample_greedy
from repro.launch.serve import build_workload, run_fixed


def _serve_both(arch, requests=4, prompt_len=6, gen=4, gen_spread=0,
                lanes=2, page_size=4):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = build_workload(cfg, requests, prompt_len, gen,
                              gen_spread=gen_spread)
    fixed = run_fixed(model, params, [r.clone() for r in workload],
                      batch=requests)
    max_len = prompt_len + max(r.max_new_tokens for r in workload)
    tw = -(-max_len // page_size)
    ecfg = EngineConfig(lanes=lanes, page_size=page_size,
                        num_pages=lanes * tw + 1, max_len=max_len)
    engine = ServeEngine(model, params, ecfg)
    cont, _ = engine.run(workload)
    return fixed, cont


def _assert_identical(fixed, cont):
    assert set(fixed) == set(cont)
    for rid in fixed:
        np.testing.assert_array_equal(fixed[rid], cont[rid], err_msg=rid)


def test_continuous_matches_fixed_dense_attn():
    _assert_identical(*_serve_both("qwen2-0.5b"))


def test_continuous_matches_fixed_mixed_gen_lane_reuse():
    """Mixed generation lengths with fewer lanes than requests: short
    requests finish early, their lanes and pages are reused by later
    prefills, and the output still matches the lockstep reference."""
    fixed, cont = _serve_both("qwen2-0.5b", requests=6, gen=5, gen_spread=3,
                              lanes=2)
    lens = sorted(len(v) for v in cont.values())
    assert lens == [2, 2, 2, 8, 8, 8]
    _assert_identical(fixed, cont)


@pytest.mark.parametrize("arch", ["minicpm3-4b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_continuous_matches_fixed_other_families(arch):
    # MLA latent cache, pure-SSM state rows, recurrent + sliding-window mix
    _assert_identical(*_serve_both(arch))


def test_engine_rejects_encdec():
    cfg = get_config("seamless-m4t-large-v2", smoke=True)
    model = LM(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ServeEngine(model, params, EngineConfig(lanes=2, num_pages=4, max_len=8))


# ---------------------------------------------------------------------------
# sampling / feed helpers
# ---------------------------------------------------------------------------


def test_sample_greedy_last_position_argmax():
    logits = jnp.zeros((2, 3, 5)).at[0, -1, 4].set(9.0).at[1, -1, 2].set(9.0)
    # earlier positions must not matter
    logits = logits.at[0, 0, 1].set(99.0)
    tok = sample_greedy(logits)
    assert tok.shape == (2, 1)
    assert tok.dtype == jnp.int32
    assert tok.tolist() == [[4], [2]]


def test_feed_builder_caches_frames_per_shape():
    cfg = get_config("seamless-m4t-large-v2", smoke=True)
    assert cfg.frontend
    fb = FeedBuilder(cfg)
    toks = np.zeros((2, 4), np.int32)
    f1, f2 = fb(toks), fb(toks)
    assert f1["frames"] is f2["frames"]                # cached, not rebuilt
    assert f1["frames"].shape == (2, 4, cfg.d_model)
    f3 = fb(np.zeros((1, 4), np.int32))
    assert f3["frames"].shape[0] == 1                  # new shape, new buffer
    assert f1["tokens"].dtype == jnp.int32


def test_feed_builder_tokens_only_for_text_models():
    cfg = get_config("qwen2-0.5b", smoke=True)
    fb = FeedBuilder(cfg)
    assert set(fb(np.zeros((1, 3), np.int32))) == {"tokens"}
