"""Continuous-batching engine vs the fixed-batch reference.

The engines must be token-identical: prefill reuses the dense path, the
paged commit/gather preserves logical KV order, and per-lane masking matches
the lockstep decode.  Also covers the factored-out sampling/feed helpers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import LM
from repro.serving import (EngineConfig, FeedBuilder, ServeEngine, lane_keys,
                           sample_greedy, sample_topk)
from repro.launch.serve import build_workload, run_fixed


def _serve_both(arch, requests=4, prompt_len=6, gen=4, gen_spread=0,
                lanes=2, page_size=4, prefix_len=0, extra_pages=0,
                **engine_kw):
    cfg = get_config(arch, smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = build_workload(cfg, requests, prompt_len, gen,
                              gen_spread=gen_spread, prefix_len=prefix_len)
    fixed = run_fixed(model, params, [r.clone() for r in workload],
                      batch=requests)
    max_len = prompt_len + max(r.max_new_tokens for r in workload)
    tw = -(-max_len // page_size)
    ecfg = EngineConfig(lanes=lanes, page_size=page_size,
                        num_pages=lanes * tw + 1 + extra_pages,
                        max_len=max_len, **engine_kw)
    engine = ServeEngine(model, params, ecfg)
    cont, _ = engine.run(workload)
    return fixed, cont


def _assert_identical(fixed, cont):
    assert set(fixed) == set(cont)
    for rid in fixed:
        np.testing.assert_array_equal(fixed[rid], cont[rid], err_msg=rid)


def test_continuous_matches_fixed_dense_attn():
    _assert_identical(*_serve_both("qwen2-0.5b"))


def test_continuous_matches_fixed_mixed_gen_lane_reuse():
    """Mixed generation lengths with fewer lanes than requests: short
    requests finish early, their lanes and pages are reused by later
    prefills, and the output still matches the lockstep reference."""
    fixed, cont = _serve_both("qwen2-0.5b", requests=6, gen=5, gen_spread=3,
                              lanes=2)
    lens = sorted(len(v) for v in cont.values())
    assert lens == [2, 2, 2, 8, 8, 8]
    _assert_identical(fixed, cont)


@pytest.mark.parametrize("arch", ["minicpm3-4b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_continuous_matches_fixed_other_families(arch):
    # MLA latent cache, pure-SSM state rows, recurrent + sliding-window mix
    _assert_identical(*_serve_both(arch))


DECODER_ARCHS = ["qwen2-0.5b", "qwen3-14b", "gemma3-4b", "minicpm3-4b",
                 "mixtral-8x7b", "deepseek-v2-236b", "mamba2-2.7b",
                 "recurrentgemma-9b", "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_continuous_matches_fixed_sharing_and_chunking(arch):
    """Every decoder-only arch, with CoW prefix sharing and chunked prefill
    requested: the engine gates each feature to the families where it is
    exact, and the token stream must stay identical to the lockstep
    reference either way."""
    fixed, cont = _serve_both(arch, requests=5, prompt_len=10, gen=4,
                              gen_spread=2, lanes=2, page_size=4,
                              prefix_len=8, extra_pages=4,
                              prefill_chunk=8, prefix_share=True)
    _assert_identical(fixed, cont)


def test_prefill_signature_count_bounded():
    """32 prompts of every length 1..32 admitted one per step must lower to
    at most log2(max_len) distinct (len bucket, batch, span) signatures —
    the retrace-collapse property of bucketed batched prefill."""
    import math

    from repro.serving import ServeRequest

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    reqs = [ServeRequest(request_id=f"r{n:02d}",
                         prompt=rng.randint(0, cfg.vocab, size=n).astype(np.int32),
                         max_new_tokens=2, arrival_step=n - 1)
            for n in range(1, 33)]
    max_len = 64
    tw = -(-max_len // 4)
    ecfg = EngineConfig(lanes=2, page_size=4, num_pages=2 * tw + 1,
                        max_len=max_len)
    engine = ServeEngine(model, params, ecfg)
    engine.run(reqs)
    assert len(engine.prefill_signatures) <= math.log2(max_len)


def test_engine_rejects_encdec():
    cfg = get_config("seamless-m4t-large-v2", smoke=True)
    model = LM(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        ServeEngine(model, params, EngineConfig(lanes=2, num_pages=4, max_len=8))


# ---------------------------------------------------------------------------
# sampling / feed helpers
# ---------------------------------------------------------------------------


def test_sample_greedy_last_position_argmax():
    logits = jnp.zeros((2, 3, 5)).at[0, -1, 4].set(9.0).at[1, -1, 2].set(9.0)
    # earlier positions must not matter
    logits = logits.at[0, 0, 1].set(99.0)
    tok = sample_greedy(logits)
    assert tok.shape == (2, 1)
    assert tok.dtype == jnp.int32
    assert tok.tolist() == [[4], [2]]


def test_sample_topk_zero_temperature_is_greedy():
    logits = jnp.zeros((2, 2, 8)).at[0, -1, 3].set(5.0).at[1, -1, 6].set(5.0)
    logits = logits.at[0, 0, 1].set(99.0)              # earlier position: junk
    keys = lane_keys(jnp.array([0, 1]), jnp.array([0, 0]))
    tok = sample_topk(logits, 0.0, 0, keys)
    assert tok.shape == (2, 1)
    assert tok.dtype == jnp.int32
    assert tok.tolist() == [[3], [6]]


def test_sample_topk_support_and_determinism():
    # two near-equal leaders: k=2 must draw both, and never anything else
    logits = jnp.tile(jnp.array([[[0.0, 5.0, 4.9, 3.0, -2.0]]]), (4, 1, 1))
    seeds = jnp.arange(4)
    draws = [sample_topk(logits, 1.5, 2, lane_keys(seeds, jnp.full((4,), p)))
             for p in range(50)]
    flat = np.asarray(jnp.concatenate(draws)).ravel().tolist()
    assert set(flat) == {1, 2}
    # same (seed, position) keys replay the same tokens
    again = sample_topk(logits, 1.5, 2, lane_keys(seeds, jnp.full((4,), 7)))
    np.testing.assert_array_equal(np.asarray(again), np.asarray(draws[7]))
    # distinct seeds are distinct streams: across 50 positions the four
    # lanes cannot all be identical
    per_lane = np.asarray(jnp.concatenate(draws, axis=1))  # (4, 50)
    assert any(not np.array_equal(per_lane[0], per_lane[i]) for i in (1, 2, 3))


def test_feed_builder_caches_frames_per_shape():
    cfg = get_config("seamless-m4t-large-v2", smoke=True)
    assert cfg.frontend
    fb = FeedBuilder(cfg)
    toks = np.zeros((2, 4), np.int32)
    f1, f2 = fb(toks), fb(toks)
    assert f1["frames"] is f2["frames"]                # cached, not rebuilt
    assert f1["frames"].shape == (2, 4, cfg.d_model)
    f3 = fb(np.zeros((1, 4), np.int32))
    assert f3["frames"].shape[0] == 1                  # new shape, new buffer
    assert f1["tokens"].dtype == jnp.int32


def test_feed_builder_tokens_only_for_text_models():
    cfg = get_config("qwen2-0.5b", smoke=True)
    fb = FeedBuilder(cfg)
    assert set(fb(np.zeros((1, 3), np.int32))) == {"tokens"}
