"""Pulse-engine tests: Assumption 3.4 statistics, mode agreement, bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device, pulse


CFG = device.DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1, sigma_c2c=0.0)


def _dp(shape=(64, 64), key=0):
    return device.sample_device(jax.random.PRNGKey(key), shape, CFG)


def test_discretization_unbiased():
    """E[b_k] = 0: the stochastically-rounded update matches the exact
    analog update in expectation (Assumption 3.4)."""
    dp = _dp()
    w = jnp.zeros((64, 64))
    dw = jnp.full((64, 64), 0.0033)  # fractional pulses
    exact = jnp.asarray(
        __import__("repro.kernels.ref", fromlist=["x"]).analog_update_expected_ref(
            w, dw, dp["gamma"], dp["rho"], tau_min=CFG.tau_min, tau_max=CFG.tau_max))
    acc = jnp.zeros_like(w)
    n = 200
    for i in range(n):
        acc = acc + pulse.analog_update(w, dw, dp, CFG, jax.random.PRNGKey(i))
    mean_updated = acc / n
    # per-element variance is large; compare the array mean
    assert abs(float(jnp.mean(mean_updated - exact))) < 2e-4


def test_discretization_variance_scales():
    """Var[b_k] = Theta(|dw| * dw_min) for sub-pulse updates."""
    dp = device.DeviceParams(gamma=jnp.ones((128, 128)), rho=jnp.zeros((128, 128)))
    w = jnp.zeros((128, 128))
    variances = []
    for mag in (0.002, 0.004):
        dw = jnp.full((128, 128), mag)
        samples = []
        for i in range(64):
            out = pulse.analog_update(w, dw, dp, CFG, jax.random.PRNGKey(i))
            samples.append(np.asarray(out - w))
        v = np.var(np.stack(samples), axis=0).mean()
        variances.append(v)
    # Bernoulli rounding: Var = dw_min^2 p(1-p); p = 0.2 vs 0.4 gives
    # (0.4*0.6)/(0.2*0.8) = 1.5 exactly
    ratio = variances[1] / variances[0]
    assert 1.3 < ratio < 1.7, ratio


def test_bounds_respected():
    dp = _dp((32, 32))
    w = jnp.full((32, 32), 0.99)
    dw = jnp.full((32, 32), 0.5)
    out = pulse.analog_update(w, dw, dp, CFG, jax.random.PRNGKey(0))
    assert float(jnp.max(out)) <= CFG.tau_max + 1e-6


def test_pulse_train_matches_fused_small_updates():
    """For |dw| ~ dw_min the BL-deep pulse train and the fused single-shot
    update agree in expectation (response drift over one pulse is O(dwmin))."""
    dp = _dp((128, 128), key=5)
    w = 0.2 * jnp.ones((128, 128))
    dw = jnp.full((128, 128), 0.03)
    accs = {"fused": jnp.zeros_like(w), "train": jnp.zeros_like(w)}
    n = 50
    for i in range(n):
        for mode in accs:
            accs[mode] = accs[mode] + pulse.analog_update(
                w, dw, dp, CFG, jax.random.PRNGKey(i), bl=10, mode=mode)
    diff = float(jnp.mean(jnp.abs(accs["fused"] / n - accs["train"] / n)))
    assert diff < 2e-3, diff


def test_zs_step_moves_toward_sp():
    dp = device.sample_device(
        jax.random.PRNGKey(9), (64, 64),
        device.DeviceConfig(dw_min=0.01, sigma_pm=0.5, sigma_d2d=0.1))
    cfg = device.DeviceConfig(dw_min=0.01, sigma_pm=0.5, sigma_d2d=0.1)
    sp = device.symmetric_point(dp, cfg)
    w = jnp.zeros((64, 64))
    d0 = float(jnp.mean(jnp.abs(w - sp)))
    for i in range(400):
        sign = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(i), 0.5, w.shape), 1.0, -1.0)
        w = pulse.zs_step(w, sign * cfg.dw_min, dp, cfg)
    d1 = float(jnp.mean(jnp.abs(w - sp)))
    assert d1 < 0.5 * d0, (d0, d1)
