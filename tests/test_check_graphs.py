"""check_graphs CLI: report schema, baseline diff semantics, lint pass."""
import copy
import importlib.util
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_cli():
    spec = importlib.util.spec_from_file_location(
        "check_graphs", os.path.join(REPO, "tools", "check_graphs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


cg = _load_cli()


def _fake_report():
    from repro.analysis import graph_contracts as gc

    contracts = []
    for name in sorted(gc.CONTRACTS):
        contracts.append({
            "name": name, "ok": True, "violations": [],
            "stats": {"restacks": 0, "aliased_outputs": 2,
                      "max_copy_bytes": 0, "host_transfer_ops": 0,
                      "dtypes": ["f32"], "whiles": 0,
                      "whiles_unannotated": 0, "hbm_bytes": 1.0,
                      "collective_bytes": 0.0, "flops": 0.0},
            "limits": gc.CONTRACTS[name].limits_json(),
        })
    return {"version": cg.SCHEMA_VERSION, "ok": True, "mutant": None,
            "contracts": contracts, "lint": []}


def test_report_schema_validates():
    from repro.serving.schema import SchemaError, validate

    report = _fake_report()
    validate(report, cg.REPORT_SCHEMA)
    bad = copy.deepcopy(report)
    del bad["contracts"][0]["stats"]
    with pytest.raises(SchemaError):
        validate(bad, cg.REPORT_SCHEMA)


def test_baseline_roundtrip_passes():
    report = _fake_report()
    baseline = cg.baseline_from_report(report)
    assert cg.diff_baseline(report, baseline) == []


def test_new_violation_fails_check():
    report = _fake_report()
    baseline = cg.baseline_from_report(report)
    report["contracts"][0]["violations"].append(
        {"rule": "restack", "detail": "planted"})
    fails = cg.diff_baseline(report, baseline)
    assert any("restack" in f for f in fails)


def test_missing_and_extra_contracts_fail_check():
    report = _fake_report()
    baseline = cg.baseline_from_report(report)
    # baseline knows a contract the registry lost -> coverage shrank
    baseline["contracts"]["ghost"] = {"limits": {}, "stats": {}}
    fails = cg.diff_baseline(report, baseline)
    assert any("no longer registered" in f for f in fails)
    # registry has a contract the baseline has never seen -> stale baseline
    baseline2 = cg.baseline_from_report(report)
    del baseline2["contracts"][report["contracts"][0]["name"]]
    fails2 = cg.diff_baseline(report, baseline2)
    assert any("not in baseline" in f for f in fails2)


def test_loosened_limit_fails_check():
    report = _fake_report()
    baseline = cg.baseline_from_report(report)
    name = report["contracts"][0]["name"]
    # baseline remembers a tighter ceiling than the registry now declares
    baseline["contracts"][name]["limits"]["max_hbm_bytes"] = 1.0
    fails = cg.diff_baseline(report, baseline)
    assert any("loosened" in f and name in f for f in fails)


def test_new_lint_finding_fails_check():
    report = _fake_report()
    baseline = cg.baseline_from_report(report)
    report["lint"].append({"path": "src/repro/core/x.py", "line": 3,
                           "rule": "tracer-sync", "message": "bad"})
    fails = cg.diff_baseline(report, baseline)
    assert any("tracer-sync" in f for f in fails)


def test_checked_in_baseline_matches_registry():
    """GRAPH_BASELINE.json must track the CONTRACTS registry (the CI gate
    re-lowers everything; here we just pin names and limit drift)."""
    from repro.analysis import graph_contracts as gc
    from repro.analysis.contracts import loosened

    with open(os.path.join(REPO, "GRAPH_BASELINE.json")) as f:
        baseline = json.load(f)
    assert set(baseline["contracts"]) == set(gc.CONTRACTS)
    for name, entry in baseline["contracts"].items():
        assert loosened(gc.CONTRACTS[name], entry["limits"]) == []


def test_cli_lint_only_smoke():
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_graphs.py"),
         "--lint-only"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "lint: clean" in out.stdout
