"""Data pipeline tests: determinism, shapes, prefetch."""
import numpy as np

from repro.data import BigramLM, ImageDataset, Prefetcher


def test_bigram_deterministic_and_learnable():
    d1 = BigramLM(vocab=64, seed=5)
    d2 = BigramLM(vocab=64, seed=5)
    a = d1.batch(3, 4, 16)
    b = d2.batch(3, 4, 16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    # learnable structure: each token has only 8 possible successors
    succ = {}
    big = d1.batch(0, 64, 256)
    for t, l in zip(big["tokens"].ravel(), big["labels"].ravel()):
        succ.setdefault(int(t), set()).add(int(l))
    assert max(len(v) for v in succ.values()) <= 8


def test_bigram_host_sharding_consistency():
    """Host h slicing rows of the global batch sees the same data the
    single-host path produces (multi-host determinism contract)."""
    d = BigramLM(vocab=32, seed=1)
    full = d.batch(7, 8, 16)["tokens"]
    again = d.batch(7, 8, 16)["tokens"]
    np.testing.assert_array_equal(full, again)


def test_image_dataset():
    ds = ImageDataset(n_train=256, n_test=64, seed=2)
    batches = list(ds.epoch(0, 32))
    assert len(batches) == 8
    assert batches[0]["x"].shape == (32, 28, 28, 1)
    # different epochs shuffle differently
    b1 = next(iter(ds.epoch(1, 32)))
    assert not np.array_equal(batches[0]["y"], b1["y"]) or True
    # classes are separable enough for a linear probe to beat chance
    x = ds.x_train.reshape(len(ds.x_train), -1)
    y = ds.y_train
    centroids = np.stack([x[y == c].mean(0) for c in range(10)])
    pred = np.argmin(((ds.x_test.reshape(len(ds.x_test), -1)[:, None]
                       - centroids[None]) ** 2).sum(-1), axis=1)
    acc = (pred == ds.y_test).mean()
    assert acc > 0.5, acc


def test_prefetcher():
    seen = []

    def producer(step):
        return {"x": np.full((2, 2), step)}

    pf = Prefetcher(producer, depth=2)
    it = iter(pf)
    for expect in range(4):
        batch = next(it)
        seen.append(int(batch["x"][0, 0]))
    pf.close()
    assert seen == [0, 1, 2, 3]
