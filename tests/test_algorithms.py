"""Algorithm-level tests on a noisy quadratic (fast, deterministic seeds)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device
from repro.core.digital_opt import DigitalOptConfig, ScheduleConfig
from repro.core.tile import ALGORITHMS, TileConfig
from repro.core.trainer import AnalogTrainer, TrainerConfig

WSTAR = jax.random.normal(jax.random.PRNGKey(1), (24, 24)) * 0.05


def _loss_fn(params, batch, rng):
    noise = 0.02 * jax.random.normal(rng, params["w"].shape)
    resid = params["w"] - WSTAR
    loss = 0.5 * jnp.sum(resid ** 2)
    surrogate = jnp.sum(params["w"] * jax.lax.stop_gradient(resid + noise))
    return surrogate, {"true_loss": loss}


def _run(algorithm, steps=400, ref_mean=0.3, ref_std=0.2, **tile_kw):
    dev_p = device.DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1,
                                sigma_c2c=0.05, ref_mean=ref_mean, ref_std=ref_std)
    dev_w = device.DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1,
                                sigma_c2c=0.05)
    kw = dict(lr_p=0.5, lr_w=0.5, gamma=0.1, eta=0.1, chopper_p=0.1)
    kw.update(tile_kw)
    cfg = TrainerConfig(
        tile=TileConfig(algorithm=algorithm, device_p=dev_p, device_w=dev_w, **kw),
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
    )
    trainer = AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)
    state = trainer.init(jax.random.PRNGKey(2), {"w": jnp.zeros((24, 24))})
    step = trainer.jit_step()
    metrics = {}
    for _ in range(steps):
        state, metrics = step(state, jnp.zeros(()))
    return state, {k: float(v) for k, v in metrics.items()}


@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_all_algorithms_reduce_loss(algorithm):
    _, m = _run(algorithm)
    initial = 0.5 * float(jnp.sum(WSTAR ** 2))
    assert m["true_loss"] < 0.9 * initial, (algorithm, m["true_loss"], initial)


def test_erider_tracks_sp():
    """E-RIDER's Q converges toward the P-device SP (Thm 3.7 metric)."""
    _, m = _run("erider", steps=800, eta=0.3)
    initial_err = 0.3 ** 2 + 0.2 ** 2  # E[(0 - w_sp)^2]
    assert m["tile/sp_err"] < 0.75 * initial_err, m["tile/sp_err"]


def test_chopping_accelerates_tracking():
    """Fig. 5 mechanism: p > 0 tracks the SP better than p = 0 (RIDER)."""
    _, m_rider = _run("erider", steps=800, eta=0.3, chopper_p=0.0)
    _, m_er = _run("erider", steps=800, eta=0.3, chopper_p=0.1)
    assert m_er["tile/sp_err"] <= m_rider["tile/sp_err"] * 1.1


def test_erider_programming_events_sparse():
    """Q-tilde reprogramming only happens on chopper flips (~p per step)."""
    _, m = _run("erider", steps=400, chopper_p=0.05)
    assert m["tile/prog_events"] < 0.15 * 400


def test_residual_with_perfect_sp_beats_zero_sp():
    """Alg. 4: a perfect static SP estimate beats an uncalibrated zero one."""
    dev_p = device.DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1,
                                ref_mean=0.4, ref_std=0.1)
    dev_w = device.DeviceConfig(dw_min=0.01, sigma_pm=0.3, sigma_d2d=0.1)
    cfg = TrainerConfig(
        tile=TileConfig(algorithm="residual", device_p=dev_p, device_w=dev_w,
                        lr_p=0.5, lr_w=0.5, gamma=0.1),
        digital=DigitalOptConfig(kind="sgd"),
        schedule=ScheduleConfig(kind="constant", base_lr=0.1),
    )
    trainer = AnalogTrainer(_loss_fn, cfg, analog_filter=lambda p, l: True)

    def run(sp_est):
        state = trainer.init(jax.random.PRNGKey(2), {"w": jnp.zeros((24, 24))},
                             sp_estimates=sp_est)
        step = trainer.jit_step()
        m = {}
        for _ in range(500):
            state, m = step(state, jnp.zeros(()))
        return float(m["true_loss"])

    # exact per-tile SP: regenerate the same device draw as trainer.init
    kk = jax.random.fold_in(jax.random.PRNGKey(2), 0)
    kp, _, _ = jax.random.split(kk, 3)
    dp = device.sample_device(kp, (24, 24), dev_p)
    sp = device.symmetric_point(dp, dev_p)
    loss_perfect = run({"w": sp})
    loss_zero = run(None)
    assert loss_perfect < loss_zero, (loss_perfect, loss_zero)


def test_hash_rng_path_runs():
    _, m = _run("erider", steps=100, rng="hash", store_device=False)
    assert np.isfinite(m["true_loss"])
